"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps on the synthetic LM stream, with checkpoints, then reload and
serve a few tokens from it.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

(~100M params is the largest model that trains in reasonable wall-clock on
this CPU container; on TPU the identical code path scales through the mesh
in launch/train.py.)
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.models.config import AttnConfig, ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import ServeEngine
from repro.train.loop import Trainer, TrainerConfig


def config_100m() -> ModelConfig:
    return ModelConfig(
        name="llama-100m", family="dense", num_layers=8, d_model=512,
        d_ff=1536, vocab_size=2048,
        attn=AttnConfig(num_heads=8, num_kv_heads=4, head_dim=64),
        pattern=("attn",), ffn_type="glu", norm_type="rmsnorm",
        weight_bits=4)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = config_100m()
    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(
            cfg,
            AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
            TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=100,
                          num_microbatches=2),
            global_batch=args.batch, seq_len=args.seq)
        from repro.models.params import count_params
        print(f"params: {count_params(trainer.defs)/1e6:.1f}M")
        params, _, history = trainer.run(args.steps, log_every=25)
        for h in history:
            print(f"step {h['step']:4d}  loss {h['loss']:.3f}  "
                  f"ppl {h['ppl']:8.1f}  {h['sec_per_step']:.2f}s/step")
        uniform = float(jnp.log(cfg.vocab_size))
        final = history[-1]["loss"]
        print(f"\nfinal loss {final:.3f} vs uniform {uniform:.3f} — "
              f"{'LEARNED' if final < uniform - 1 else 'check hyperparams'}")

        # serve a few tokens from the trained weights (dense bf16)
        eng = ServeEngine(cfg, params, max_seq=64, batch_slots=2)
        toks = eng.generate(jnp.zeros((2, 8), jnp.int32), max_new=16)
        print("sampled continuation:", toks[0, 8:].tolist())


if __name__ == "__main__":
    main()

"""Residency sessions: place-then-execute decode through the MVDRAM engine.

The paper's end-to-end wins come from weights LIVING in DRAM across the
whole pipeline (§IV, §VI). This example walks the new two-phase API:

  ① place    register every linear of a small transformer block — the
             engine's `DramPool` gives each matrix a persistent
             (channel, bank, row-range) home; heterogeneous shapes
             co-reside in one pool
  ② compile  fuse the block's GeMV sequence into one `GemvProgram`
             (q/k/v share waves; weight rows staged exactly once)
  ③ decode   run decode steps against the resident rows — zero weight
             re-staging, outputs bit-identical to per-layer `gemv`
  ④ fused    the default `run` EXECUTES the fused schedule wave-major
             (one batched simulator step per global wave, boundary waves
             spanning layers); `layer_major=True` is the retained oracle
  ⑤ faults   a fault-storm engine: injected bit-flips are caught by ABFT
             checksums, retried, weak banks quarantined + restaged, and
             past the budget the layer degrades to the host jnp backend
             while `gemv` keeps serving correct outputs

    PYTHONPATH=src python examples/resident_decode.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.backends import SIM
from repro.core.engine import MVDRAMEngine
from repro.core.pud.gemv import PudGeometry
from repro.core.quant import QuantSpec

rng = np.random.default_rng(0)
geom = PudGeometry(subarray_cols=64, n_sub_max=32)
engine = MVDRAMEngine(geom=geom)

# -- ① place: a block's linears co-reside in one DramPool --------------------
D, H, F = 256, 192, 512
layers = {
    "blk0/wq": (D, H), "blk0/wk": (D, H), "blk0/wv": (D, H),
    "blk0/wo": (H, D),
    "blk0/up": (D, F), "blk0/gate": (D, F), "blk0/down": (F, D),
}
handles = []
for name, (n, m) in layers.items():
    w = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    handles.append(engine.register(name, w, QuantSpec(bits=4),
                                   a_spec=QuantSpec(bits=2)))
stats = engine.residency_stats()
print(f"pool: {stats['placements']} resident matrices, "
      f"{stats['used_rows']}/{stats['total_rows']} rows "
      f"({stats['utilization']:.2%}), staged {stats['staged_bits']} bits once")

# -- ② compile: one fused decode program (q/k/v and up/gate share waves) -----
program = engine.compile(
    handles, groups=[[0, 1, 2], [3], [4, 5], [6]])
print(f"program: {program}")

# -- ③ decode: resident steps, zero re-staging -------------------------------
B = 2
for step in range(3):
    acts = [jnp.asarray(rng.normal(size=(B, n)), jnp.float32)
            for (n, _m) in layers.values()]
    outs, report = program.run(acts)
    print(f"step {step}: {len(outs)} GeMVs, "
          f"re-staged bits = {report.repeated_staging.host_bits_written} "
          f"(one-time placement staging was "
          f"{report.staged.host_bits_written})")

# the per-call oracle re-pays the staging EVERY launch — same outputs
from repro.core.pud.gemv import mvdram_gemv
from repro.core.quant import quantize_activations

h0 = handles[0]
x0 = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)
out_res, rep_res = engine.gemv(h0, x0, backend=SIM)    # resident: 0 staging
aq0 = quantize_activations(x0, QuantSpec(bits=2))
out_fresh, rep_fresh = mvdram_gemv(aq0, h0.wq, geom=geom)  # fresh staging
assert np.array_equal(np.asarray(out_res), np.asarray(out_fresh))
print(f"same launch: resident stages "
      f"{rep_res.shared_preload.host_bits_written} bits, per-call oracle "
      f"re-stages {rep_fresh.shared_preload.host_bits_written} bits "
      f"(outputs bit-identical)")

# -- ④ fused wave-major execution vs the layer-major oracle ------------------
# the default `run` above already executed the FUSED schedule: one batched
# simulator step per global wave, q/k/v (and up/gate) tiles sharing
# boundary waves across layers. The retained layer-major path is the
# bit-exactness oracle — outputs and per-tile OpCounts identical, only the
# wave axis (and wall-clock) differs.
import time

acts = [jnp.asarray(rng.normal(size=(B, n)), jnp.float32)
        for (n, _m) in layers.values()]
program.run(acts)                          # warm both paths
program.run(acts, layer_major=True)
t0 = time.perf_counter(); outs_f, rep_f = program.run(acts)
t_fused = time.perf_counter() - t0
t0 = time.perf_counter(); outs_l, rep_l = program.run(acts, layer_major=True)
t_layer = time.perf_counter() - t0
assert all(np.array_equal(np.asarray(a), np.asarray(b))
           for a, b in zip(outs_f, outs_l))
print(f"fused wave-major: {rep_f.waves} executed waves "
      f"(schedule fused {program.sched.waves_shared} away vs "
      f"{program.sched.waves_unfused} layer-major), "
      f"{t_fused * 1e3:.2f} ms vs {t_layer * 1e3:.2f} ms layer-major "
      f"({t_layer / t_fused:.2f}x; nightly bench row "
      f"sim.fused_wave_speedup_x holds this at >=1.3x)")

# priced: one fused resident step vs per-layer re-staging at real DRAM
# width, plus the SIMULATED-width price reconciled against the waves the
# fused run actually executed (measurement, not model)
cost = engine.price_program(program, batch=B,
                            usable_cols=geom.real_cols)
measured = engine.price_program(program, batch=B, executed=rep_f)
print(f"priced decode step: {cost.t_total * 1e3:.3f} ms resident vs "
      f"{cost.t_sequential_total * 1e3:.3f} ms per-layer re-staging "
      f"({cost.residency_speedup:.2f}x; {cost.waves_shared} waves fused, "
      f"weight_load_bits={cost.weight_load_bits}); executed-wave bank "
      f"time {measured.t_compute * 1e6:.1f} us at simulated width")

# -- ⑤ fault storm: ABFT → retry → quarantine → host fallback ----------------
# a deliberately hostile DRAM: 5% of cells are weak and ALWAYS flip. The
# aggressive policy walks the whole recovery ladder in one launch — ABFT
# checksums localize corrupt (request, tile) cells, one wave retry is
# attempted, striking banks are quarantined and their tenants restaged,
# and once restaging can't outrun the storm the layer degrades to the
# host jnp backend. Serving never stops and outputs stay correct.
from repro.core.pud.faults import FaultModel, FaultPolicy

storm = FaultModel(weak_cell_rate=0.05, weak_flip_prob=1.0, seed=23)
eng_f = MVDRAMEngine(
    geom=geom, fault_model=storm,
    fault_policy=FaultPolicy(max_wave_retries=1, quarantine_after=1,
                             degrade_after=1))
w = jnp.asarray(rng.normal(size=(D, H)), jnp.float32)
hf = eng_f.register("storm/w", w, QuantSpec(bits=4), a_spec=QuantSpec(bits=2))
x = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

out_f, _rep = eng_f.gemv(hf, x, backend=SIM)      # trips the full ladder
out_d, rep_d = eng_f.gemv(hf, x, backend=SIM)     # now served by host jnp
fs = eng_f.residency_stats()
print(f"fault storm: {fs['fault_corrupted']} corrupted cells, "
      f"{fs['fault_detected']} detected by ABFT checksums, "
      f"{fs['fault_retries']} wave retries, "
      f"{fs['fault_quarantines']} banks quarantined "
      f"({fs['quarantined_banks']} total), "
      f"{fs['fault_restages']} restages, "
      f"{fs['fault_host_fallbacks']} host fallbacks; "
      f"degraded layers = {fs['degraded_layers']}")
assert eng_f.is_degraded(hf) and rep_d is None    # host path: no sim report

# degraded outputs match a healthy engine up to float summation order
eng_h = MVDRAMEngine(geom=geom)
hh = eng_h.register("storm/w", w, QuantSpec(bits=4), a_spec=QuantSpec(bits=2))
out_h, _ = eng_h.gemv(hh, x, backend=SIM)
np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_h),
                           rtol=2e-5, atol=1e-5)
print("degraded engine keeps serving: outputs match the healthy engine")

"""Bench-regression gate + row-manifest checker for `sim_bench` JSON.

Two modes, composable in one invocation:

  regression gate (pull_request CI):
      python -m benchmarks.check_regression BENCH_new1.json BENCH_new2.json \
          --baseline BENCH_sim.json --max-drop 0.25 \
          --directions benchmarks/bench_rows.txt
    Every speedup/amortization row (name ending in `_speedup_x` or
    `_amortization_x`) present in the BASELINE must exist in the new run
    and may not drop more than `--max-drop` below the committed value —
    a PR that slows a measured ratio by >25% fails before merge. Rows the
    new run ADDS are fine (they enter the baseline when it is re-committed).
    Several run files gate on the per-row BEST: shared runners see
    multi-second memory-bandwidth contention that slows only the
    bandwidth-bound side of a ratio, so one slow window must not fail a
    healthy PR — a real regression is slow in EVERY independent run.

    The gate is DIRECTION-AWARE: a manifest line may carry an explicit
    `up` or `down` column after the row name (`--directions` points at
    the same manifest the nightly uses). `down` rows are lower-is-better
    — energy rows like `sim.energy_step_ddr4_j` — so the >max-drop gate
    flips sign: the row fails when it RISES more than `max_drop` above
    the baseline, and `merge_best` keeps the per-row MIN across runs
    (least contention-polluted is smallest for a cost). Suffix-gated
    ratio rows default to `up`; an explicit `up` column also gates a row
    whose name matches no suffix (e.g. `sim.energy_ratio_vs_cpu`).

  row manifest (nightly CI):
      python -m benchmarks.check_regression BENCH_sim.json \
          --require-rows benchmarks/bench_rows.txt
    Every row named in the manifest (one per line, optional direction
    column, `#` comments) must be present with a finite positive value,
    and the run must have recorded zero `.ERROR` entries. This replaces
    per-row `grep` lines in the workflow: a new bench row is guarded by
    ADDING ONE MANIFEST LINE, and a row that silently disappears
    (renamed, crashed, filtered) fails the job instead of going
    unchecked.

`--step-summary PATH` additionally appends a human-readable markdown
delta table (baseline vs new vs floor/ceiling, per gated row) — pointed
at `$GITHUB_STEP_SUMMARY` by the PR gate so the comparison reads off
the Actions run page instead of the artifact JSON.

Exit status 0 = all checks pass; 1 = any failure (each printed).
"""
from __future__ import annotations

import argparse
import json
import math
import sys

GATED_SUFFIXES = ("_speedup_x", "_amortization_x")
DIRECTIONS = ("up", "down")


def load_doc(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if "rows" not in doc:
        raise ValueError(f"{path} is not a sim_bench JSON (no 'rows' key)")
    return doc


def rows_by_name(doc: dict) -> dict:
    return {r["name"]: r["value"] for r in doc["rows"]}


def row_direction(name: str, directions=None) -> str | None:
    """Gate direction of a row: the manifest's explicit column wins,
    ratio-suffix rows default to 'up', everything else is ungated."""
    if directions and name in directions:
        return directions[name]
    if name.endswith(GATED_SUFFIXES):
        return "up"
    return None


def merge_best(docs, directions=None) -> dict:
    """Merge several runs' rows into one name→value map keeping the BEST
    per row — the least contention-polluted measurement of each, which is
    the MAX for higher-is-better rows (speedup ratios, the default) and
    the MIN for explicit `down` rows (costs like priced energy)."""
    merged: dict = {}
    for doc in docs:
        for name, value in rows_by_name(doc).items():
            if not isinstance(value, (int, float)) \
                    or not math.isfinite(value):
                continue
            down = row_direction(name, directions) == "down"
            if name not in merged or (value < merged[name] if down
                                      else value > merged[name]):
                merged[name] = value
    return merged


def check_errors(doc: dict, label: str) -> list:
    """The bench harness records per-benchmark failures instead of dying;
    a gated run must have recorded none."""
    return [f"{label}: benchmark {e['bench']!r} errored: {e['error']}"
            for e in doc.get("errors", [])]


def gate_bound(base: float, direction: str, max_drop: float) -> float:
    """The failing threshold for one row: a floor below the baseline for
    `up` rows, a ceiling above it for `down` rows."""
    return base * ((1.0 + max_drop) if direction == "down"
                   else (1.0 - max_drop))


def check_drop(new_rows: dict, base_doc: dict, max_drop: float,
               directions=None) -> list:
    """Gated rows of the baseline must survive in the new run
    (`new_rows`: name→value, e.g. `merge_best` of the run files) within
    (1 - max_drop)× the committed value — or, for `down` rows, within
    (1 + max_drop)× (a cost regressing is a RISE)."""
    failures = []
    for name, base in sorted(rows_by_name(base_doc).items()):
        direction = row_direction(name, directions)
        if direction is None:
            continue
        if not isinstance(base, (int, float)) or not math.isfinite(base):
            continue
        if name not in new_rows:
            failures.append(
                f"gated row {name!r} (baseline {base:.4g}) is missing from "
                f"the new run")
            continue
        new = new_rows[name]
        bound = gate_bound(base, direction, max_drop)
        if not isinstance(new, (int, float)) or not math.isfinite(new):
            failures.append(f"gated row {name!r} is not finite: {new!r}")
        elif direction == "down" and new > bound:
            failures.append(
                f"{name}: {new:.4g} rose >{max_drop:.0%} above the "
                f"baseline {base:.4g} (ceiling {bound:.4g}; "
                f"lower-is-better row)")
        elif direction == "up" and new < bound:
            failures.append(
                f"{name}: {new:.4g} dropped >{max_drop:.0%} below the "
                f"baseline {base:.4g} (floor {bound:.4g})")
    return failures


def read_manifest(path: str) -> list:
    """Row NAMES from a manifest (first token per line; an optional
    direction column and `#` comments are ignored)."""
    names = []
    with open(path) as f:
        for line in f:
            parts = line.split("#", 1)[0].split()
            if parts:
                names.append(parts[0])
    return names


def read_directions(path: str) -> dict:
    """name → 'up' | 'down' for manifest rows carrying an explicit
    direction column; rows without one are absent (suffix-gated rows
    default to 'up' via `row_direction`)."""
    directions: dict = {}
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            parts = line.split("#", 1)[0].split()
            if len(parts) <= 1:
                continue
            if len(parts) > 2 or parts[1] not in DIRECTIONS:
                raise ValueError(
                    f"{path}:{ln}: expected '<row-name> [up|down]', "
                    f"got {line.strip()!r}")
            directions[parts[0]] = parts[1]
    return directions


def check_required(rows: dict, required) -> list:
    """Every manifest row must exist with a finite positive value."""
    failures = []
    for name in required:
        if name not in rows:
            failures.append(f"required row {name!r} missing from the run")
            continue
        v = rows[name]
        if not isinstance(v, (int, float)) or isinstance(v, bool) \
                or not math.isfinite(v) or v <= 0:
            failures.append(
                f"required row {name!r} has a non-positive/non-finite "
                f"value: {v!r}")
    return failures


def step_summary_table(new_rows: dict, base_doc: dict, max_drop: float,
                       directions=None, run_labels=()) -> str:
    """Markdown delta table of every gated baseline row: committed value,
    per-row best of the new runs, the failing floor/ceiling, the relative
    delta, and the verdict — what lands in `$GITHUB_STEP_SUMMARY`."""
    base_rows = rows_by_name(base_doc)
    lines = ["## Bench regression gate", ""]
    if run_labels:
        lines += [f"Per-row best of {len(run_labels)} run(s): "
                  + ", ".join(f"`{r}`" for r in run_labels), ""]
    lines += [f"| row | dir | baseline | new (best) | "
              f"{'floor / ceiling'} | Δ | gate |",
              "|---|---|---:|---:|---:|---:|---|"]
    for name, base in sorted(base_rows.items()):
        direction = row_direction(name, directions)
        if direction is None or not isinstance(base, (int, float)) \
                or not math.isfinite(base):
            continue
        bound = gate_bound(base, direction, max_drop)
        new = new_rows.get(name)
        if not isinstance(new, (int, float)) or not math.isfinite(new):
            verdict, delta, new_s = "❌ missing", "—", "—"
        else:
            delta = f"{(new - base) / base:+.2%}"
            new_s = f"{new:.4g}"
            regressed = (new > bound if direction == "down"
                         else new < bound)
            verdict = "❌ fail" if regressed else "✅ ok"
        lines.append(f"| `{name}` | {direction} | {base:.4g} | {new_s} | "
                     f"{bound:.4g} | {delta} | {verdict} |")
    added = sorted(n for n in new_rows
                   if n not in base_rows
                   and row_direction(n, directions) is not None)
    if added:
        lines += ["", "New gated rows (enter the baseline when it is "
                  "re-committed): " + ", ".join(f"`{n}`" for n in added)]
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new_json", nargs="+",
                    help="sim_bench --json output(s) to check; several "
                         "independent runs gate on the per-row best")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="committed baseline JSON for the >max-drop gate")
    ap.add_argument("--max-drop", type=float, default=0.25,
                    help="max allowed fractional drop of a gated ratio row "
                         "below the baseline (default 0.25); for `down` "
                         "rows, max allowed fractional RISE above it")
    ap.add_argument("--directions", default=None, metavar="MANIFEST",
                    help="manifest whose optional per-row up/down column "
                         "sets gate directions (energy rows gate "
                         "lower-is-better)")
    ap.add_argument("--require-rows", default=None, metavar="MANIFEST",
                    help="row-name manifest every run must produce")
    ap.add_argument("--step-summary", default=None, metavar="PATH",
                    help="append a markdown baseline-vs-new delta table "
                         "here (point at $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args(argv)
    if args.baseline is None and args.require_rows is None:
        ap.error("nothing to check: pass --baseline and/or --require-rows")
    if not 0.0 < args.max_drop < 1.0:
        ap.error(f"--max-drop must be in (0, 1), got {args.max_drop}")
    if args.step_summary is not None and args.baseline is None:
        ap.error("--step-summary needs --baseline (it tabulates the "
                 "baseline delta)")

    directions = (read_directions(args.directions)
                  if args.directions is not None else None)
    new_docs = [load_doc(p) for p in args.new_json]
    failures = []
    for path, doc in zip(args.new_json, new_docs):
        failures += check_errors(doc, path)
    new_rows = merge_best(new_docs, directions)
    checked = []
    if args.baseline is not None:
        base_doc = load_doc(args.baseline)
        failures += check_drop(new_rows, base_doc, args.max_drop,
                               directions)
        gated = [n for n in rows_by_name(base_doc)
                 if row_direction(n, directions) is not None]
        checked.append(f"{len(gated)} gated rows vs {args.baseline} "
                       f"(max drop {args.max_drop:.0%})")
        if args.step_summary is not None:
            table = step_summary_table(new_rows, base_doc, args.max_drop,
                                       directions,
                                       run_labels=args.new_json)
            with open(args.step_summary, "a") as f:
                f.write(table)
            checked.append(f"delta table → {args.step_summary}")
    if args.require_rows is not None:
        required = read_manifest(args.require_rows)
        failures += check_required(new_rows, required)
        checked.append(f"{len(required)} manifest rows from "
                       f"{args.require_rows}")

    print(f"check_regression: {', '.join(args.new_json)}: "
          + "; ".join(checked))
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

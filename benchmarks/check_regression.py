"""Bench-regression gate + row-manifest checker for `sim_bench` JSON.

Two modes, composable in one invocation:

  regression gate (pull_request CI):
      python -m benchmarks.check_regression BENCH_new1.json BENCH_new2.json \
          --baseline BENCH_sim.json --max-drop 0.25
    Every speedup/amortization row (name ending in `_speedup_x` or
    `_amortization_x`) present in the BASELINE must exist in the new run
    and may not drop more than `--max-drop` below the committed value —
    a PR that slows a measured ratio by >25% fails before merge. Rows the
    new run ADDS are fine (they enter the baseline when it is re-committed).
    Several run files gate on the per-row BEST: shared runners see
    multi-second memory-bandwidth contention that slows only the
    bandwidth-bound side of a ratio, so one slow window must not fail a
    healthy PR — a real regression is slow in EVERY independent run.

  row manifest (nightly CI):
      python -m benchmarks.check_regression BENCH_sim.json \
          --require-rows benchmarks/bench_rows.txt
    Every row named in the manifest (one per line, `#` comments) must be
    present with a finite positive value, and the run must have recorded
    zero `.ERROR` entries. This replaces per-row `grep` lines in the
    workflow: a new bench row is guarded by ADDING ONE MANIFEST LINE, and
    a row that silently disappears (renamed, crashed, filtered) fails the
    job instead of going unchecked.

Exit status 0 = all checks pass; 1 = any failure (each printed).
"""
from __future__ import annotations

import argparse
import json
import math
import sys

GATED_SUFFIXES = ("_speedup_x", "_amortization_x")


def load_doc(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if "rows" not in doc:
        raise ValueError(f"{path} is not a sim_bench JSON (no 'rows' key)")
    return doc


def rows_by_name(doc: dict) -> dict:
    return {r["name"]: r["value"] for r in doc["rows"]}


def merge_best(docs) -> dict:
    """Merge several runs' rows into one name→value map keeping the MAX
    per row — gated rows are speedup ratios, so the best of N independent
    runs is the least contention-polluted measurement of each."""
    merged: dict = {}
    for doc in docs:
        for name, value in rows_by_name(doc).items():
            if not isinstance(value, (int, float)) \
                    or not math.isfinite(value):
                continue
            if name not in merged or value > merged[name]:
                merged[name] = value
    return merged


def check_errors(doc: dict, label: str) -> list:
    """The bench harness records per-benchmark failures instead of dying;
    a gated run must have recorded none."""
    return [f"{label}: benchmark {e['bench']!r} errored: {e['error']}"
            for e in doc.get("errors", [])]


def check_drop(new_rows: dict, base_doc: dict, max_drop: float) -> list:
    """Gated ratio rows of the baseline must survive in the new run
    (`new_rows`: name→value, e.g. `merge_best` of the run files) within
    (1 - max_drop)× the committed value."""
    failures = []
    for name, base in sorted(rows_by_name(base_doc).items()):
        if not name.endswith(GATED_SUFFIXES):
            continue
        if not isinstance(base, (int, float)) or not math.isfinite(base):
            continue
        if name not in new_rows:
            failures.append(
                f"gated row {name!r} (baseline {base:.4g}) is missing from "
                f"the new run")
            continue
        new = new_rows[name]
        floor = base * (1.0 - max_drop)
        if not isinstance(new, (int, float)) or not math.isfinite(new):
            failures.append(f"gated row {name!r} is not finite: {new!r}")
        elif new < floor:
            failures.append(
                f"{name}: {new:.4g} dropped >{max_drop:.0%} below the "
                f"baseline {base:.4g} (floor {floor:.4g})")
    return failures


def read_manifest(path: str) -> list:
    names = []
    with open(path) as f:
        for line in f:
            line = line.split("#", 1)[0].strip()
            if line:
                names.append(line)
    return names


def check_required(rows: dict, required) -> list:
    """Every manifest row must exist with a finite positive value."""
    failures = []
    for name in required:
        if name not in rows:
            failures.append(f"required row {name!r} missing from the run")
            continue
        v = rows[name]
        if not isinstance(v, (int, float)) or isinstance(v, bool) \
                or not math.isfinite(v) or v <= 0:
            failures.append(
                f"required row {name!r} has a non-positive/non-finite "
                f"value: {v!r}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new_json", nargs="+",
                    help="sim_bench --json output(s) to check; several "
                         "independent runs gate on the per-row best")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="committed baseline JSON for the >max-drop gate")
    ap.add_argument("--max-drop", type=float, default=0.25,
                    help="max allowed fractional drop of a gated ratio row "
                         "below the baseline (default 0.25)")
    ap.add_argument("--require-rows", default=None, metavar="MANIFEST",
                    help="row-name manifest every run must produce")
    args = ap.parse_args(argv)
    if args.baseline is None and args.require_rows is None:
        ap.error("nothing to check: pass --baseline and/or --require-rows")
    if not 0.0 < args.max_drop < 1.0:
        ap.error(f"--max-drop must be in (0, 1), got {args.max_drop}")

    new_docs = [load_doc(p) for p in args.new_json]
    failures = []
    for path, doc in zip(args.new_json, new_docs):
        failures += check_errors(doc, path)
    new_rows = merge_best(new_docs)
    checked = []
    if args.baseline is not None:
        base_doc = load_doc(args.baseline)
        failures += check_drop(new_rows, base_doc, args.max_drop)
        gated = [n for n in rows_by_name(base_doc)
                 if n.endswith(GATED_SUFFIXES)]
        checked.append(f"{len(gated)} gated ratio rows vs {args.baseline} "
                       f"(max drop {args.max_drop:.0%})")
    if args.require_rows is not None:
        required = read_manifest(args.require_rows)
        failures += check_required(new_rows, required)
        checked.append(f"{len(required)} manifest rows from "
                       f"{args.require_rows}")

    print(f"check_regression: {', '.join(args.new_json)}: "
          + "; ".join(checked))
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

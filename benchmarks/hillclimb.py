"""§Perf hillclimb driver: run named variants of the three selected cells
and record (hypothesis → change → before/after) evidence.

Cells (selection per the assignment):
  A. zamba2-7b × train_4k      — most collective-bound baseline
  B. musicgen-medium × train_4k — worst train roofline fraction
  C. qwen2-7b × decode_32k     — most representative of the paper
                                  (low-bit dense-LM decode GeMVs)

Each variant is one `repro.launch.dryrun` invocation (fresh process) with
knob overrides; JSON lands in benchmarks/results/perf/.

    PYTHONPATH=src python -m benchmarks.hillclimb [--only A|B|C]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

OUT = os.path.join(os.path.dirname(__file__), "results", "perf")

# variant = (cell_tag, name, dryrun args)
VARIANTS = [
    # ---- cell A: zamba2-7b train_4k (collective-bound) ----------------------
    ("A", "baseline", ["--arch", "zamba2-7b", "--shape", "train_4k",
                       "--remat", "--microbatches", "8"]),
    ("A", "seqpar", ["--arch", "zamba2-7b", "--shape", "train_4k",
                     "--remat", "--microbatches", "8",
                     "--rules", '{"seq": "model"}']),
    ("A", "mb4", ["--arch", "zamba2-7b", "--shape", "train_4k",
                  "--remat", "--microbatches", "4"]),
    ("A", "seqpar_mb4", ["--arch", "zamba2-7b", "--shape", "train_4k",
                         "--remat", "--microbatches", "4",
                         "--rules", '{"seq": "model"}']),
    ("A", "fsdp_seqpar", ["--arch", "zamba2-7b", "--shape", "train_4k",
                          "--remat", "--microbatches", "8",
                          "--rules",
                          '{"seq": "model", "embed": "data"}']),
    ("A", "fsdp_seqpar_mb4", ["--arch", "zamba2-7b", "--shape", "train_4k",
                              "--remat", "--microbatches", "4",
                              "--rules",
                              '{"seq": "model", "embed": "data"}']),
    # ---- cell B: musicgen-medium train_4k (worst train fraction) ------------
    ("B", "baseline", ["--arch", "musicgen-medium", "--shape", "train_4k",
                       "--remat", "--microbatches", "8"]),
    ("B", "mb2", ["--arch", "musicgen-medium", "--shape", "train_4k",
                  "--remat", "--microbatches", "2"]),
    ("B", "mb2_norem", ["--arch", "musicgen-medium", "--shape", "train_4k",
                        "--microbatches", "2"]),
    ("B", "seqpar_mb2", ["--arch", "musicgen-medium", "--shape", "train_4k",
                         "--remat", "--microbatches", "2",
                         "--rules", '{"seq": "model"}']),
    ("B", "seqpar_mb2_bf16flash", ["--arch", "musicgen-medium", "--shape",
                                   "train_4k", "--remat", "--microbatches",
                                   "2", "--flash-bf16",
                                   "--rules", '{"seq": "model"}']),
    ("B", "seqpar_mb2_bf16flash_blk2k", ["--arch", "musicgen-medium",
                                         "--shape", "train_4k", "--remat",
                                         "--microbatches", "2",
                                         "--flash-bf16", "--flash-block",
                                         "2048",
                                         "--rules", '{"seq": "model"}']),
    # ---- cell C: qwen2-7b decode_32k (paper-representative) -----------------
    ("C", "kv_replicated", ["--arch", "qwen2-7b", "--shape", "decode_32k",
                            "--rules", '{"kv_seq": null}']),
    ("C", "baseline", ["--arch", "qwen2-7b", "--shape", "decode_32k"]),
    ("C", "kv_int8", ["--arch", "qwen2-7b", "--shape", "decode_32k",
                      "--kv-bits", "8"]),
    ("C", "bitplane_q4", ["--arch", "qwen2-7b", "--shape", "decode_32k",
                          "--quant-bits", "4"]),
    ("C", "bitplane_q4_kv8", ["--arch", "qwen2-7b", "--shape", "decode_32k",
                              "--quant-bits", "4", "--kv-bits", "8"]),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    os.makedirs(OUT, exist_ok=True)
    for cell, name, extra in VARIANTS:
        if args.only and args.only != cell:
            continue
        out = os.path.join(OUT, f"{cell}.{name}.json")
        if os.path.exists(out):
            print(f"SKIP {cell}.{name} (cached)")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--mesh",
               "single", "--out", out] + extra
        t0 = time.time()
        r = subprocess.run(cmd, capture_output=True, text=True, timeout=1800)
        if r.returncode:
            print(f"FAIL {cell}.{name}: "
                  f"{r.stderr.strip().splitlines()[-1][:240]}")
            continue
        rec = json.load(open(out))
        rf, m = rec["roofline"], rec["memory"]
        print(f"OK {cell}.{name} ({time.time()-t0:.0f}s) "
              f"bound={rf['bound_s']:.4g}s ({rf['bottleneck']}) "
              f"mem={rf['memory_s']:.4g} coll={rf['collective_s']:.4g} "
              f"comp={rf['compute_s']:.4g} frac={rf['roofline_fraction']:.4f}"
              f" peak={m['peak_bytes_estimate']/2**30:.2f}GiB")


if __name__ == "__main__":
    main()

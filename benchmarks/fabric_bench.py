"""DRAM-fabric benchmark: multi-DIMM scale-out + spill-tier overhead.

Two sections, both deterministic (analytically priced on the DDR4/CXL
models — no wall-clock, so the rows are exactly reproducible):

Scale-out — the 4-layer resident decode block (`sim_bench._resident_block`
shapes: a q/k/v group of three 512×256 linears + a 256×512 down
projection, q=4/p=2, B=2 lanes, banked geometry) compiled on a 2-DIMM and
a 4-DIMM `FabricPool` vs the single-`DramPool` program. Outputs and the
one-time staging totals must be bit-identical across all three (placement
never affects results — only wave packing moves); the priced fabric step
overlaps per-module parts on their own command buses (paper §VI scales
across four DDR4 modules), so

    sim.fabric_scaleout_speedup_x        single-pool t_total / 2-DIMM t_total
    sim.fabric_scaleout_4dimm_speedup_x  single-pool t_total / 4-DIMM t_total

are drop-gated AND the 2-DIMM row carries a hard ≥1.6× acceptance floor
(deterministic price, so a plain assert even under --smoke).

Spill tier — six (16, 8) layers on a fabric whose single module holds two:
registration parks the cold four in the CXL capacity tier, the compiled
`FabricProgram` demand-pages them each decode step (LRU thrash by
construction), outputs stay bit-identical to a 4× bigger pool's oracle,
and the paid restage traffic reconciles EXACTLY into the priced step
(`ProgramCost.t_spill_restage == CxlModel.restage_time(bits, restages)`
to the last bit, bits cross-checked against the pool ledger):

    sim.fabric_spill_restage_overhead_x  t_total / (t_total − t_spill_restage)

require-rows-guarded only (an overhead ratio, not a speedup — tracking it
catches the restage price silently vanishing, but a smaller value is
better hardware, not a regression).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.engine import MVDRAMEngine
from repro.core.pud.fabric import FabricPool
from repro.core.pud.gemv import PudGeometry
from repro.core.quant import QuantSpec

# mirrors sim_bench: paper-representative shapes at banked geometry
N, M = 512, 256
BANKED = PudGeometry(subarray_cols=64, n_sub_max=32)
B = 2

# spill section: one subarray per bank + thin row budget → a module holds
# exactly two (16, 8) q4 layers (34 resident rows each, 54-row banks)
SPILL_GEOM = PudGeometry(subarray_rows=64, subarray_cols=32, n_sub_max=16,
                         channels=1, banks_per_channel=2,
                         subarrays_per_bank=1)
SPILL_RESERVE = 10
SPILL_LAYERS = 6


def _block(pool=None, seed=5, q_b=4, p_b=2):
    rng = np.random.default_rng(seed)
    eng = (MVDRAMEngine(geom=BANKED) if pool is None
           else MVDRAMEngine(geom=BANKED, pool=pool))
    shapes = [(N, M), (N, M), (N, M), (M, N)]
    hs = []
    for i, (n, m) in enumerate(shapes):
        w = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
        hs.append(eng.register(f"layer{i}", w, QuantSpec(bits=q_b),
                               a_spec=QuantSpec(bits=p_b)))
    prog = eng.compile(hs, groups=[[0, 1, 2], [3]])
    X = [jnp.asarray(rng.normal(size=(B, n)), jnp.float32)
         for (n, _m) in shapes]
    return eng, hs, prog, X


def sim_fabric(emit):
    """Multi-DIMM scale-out + spill-tier capacity (DRAM fabric, ISSUE 9)."""
    # -- scale-out: 1 vs 2 vs 4 DIMMs ------------------------------------
    eng1, hs1, prog1, X = _block()
    outs1, rep1 = prog1.run(X)
    cost1 = prog1.price(batch=B)
    staged1 = sum(h.placement.staged.host_bits_written for h in hs1)

    speedups = {}
    for dimms in (2, 4):
        pool = FabricPool(geom=BANKED, dimms=dimms)
        eng_f, hs_f, prog_f, _ = _block(pool=pool)
        outs_f, rep_f = prog_f.run(X)
        # placement never affects results: outputs AND per-(request, tile)
        # OpCounts bit-identical to the single-pool program
        for o1, o2 in zip(outs1, outs_f):
            np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
        for r1, r2 in zip(rep1.reports, rep_f.reports):
            for b in range(B):
                assert [c.asdict() for c in r1.requests[b].tile_runtime] \
                    == [c.asdict() for c in r2.requests[b].tile_runtime]
        assert rep_f.staged.host_bits_written == staged1
        # every module actually carries part of the block
        assert {pool.dimm_of(h.name) for h in hs_f} == set(range(dimms))
        cost_f = prog_f.price(batch=B)
        assert cost_f.spill_restage_bits == 0
        speedups[dimms] = cost1.t_total / cost_f.t_total
        assert cost_f.staged_bits == cost1.staged_bits

    emit("sim.fabric_scaleout_speedup_x", speedups[2],
         "single-pool priced decode t_total / 2-DIMM fabric t_total")
    # deterministic priced ratio → hard floor even under --smoke
    assert speedups[2] >= 1.6, \
        f"2-DIMM scale-out {speedups[2]:.2f}x below the 1.6x floor"
    emit("sim.fabric_scaleout_4dimm_speedup_x", speedups[4],
         "single-pool priced decode t_total / 4-DIMM fabric t_total")
    assert speedups[4] >= speedups[2], \
        "4 DIMMs must not price slower than 2"

    # -- spill tier: a model larger than any single pool ------------------
    rng = np.random.default_rng(7)
    ws = [jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
          for _ in range(SPILL_LAYERS)]
    pool = FabricPool(geom=SPILL_GEOM, dimms=1,
                      compute_reserve=SPILL_RESERVE)
    eng_s = MVDRAMEngine(geom=SPILL_GEOM, pool=pool, on_full="spill")
    hs_s = [eng_s.register(f"l{i}", w, QuantSpec(bits=4),
                           a_spec=QuantSpec(bits=4))
            for i, w in enumerate(ws)]
    assert len(pool.spilled()) == SPILL_LAYERS - 2   # the module holds two
    prog_s = eng_s.compile([h.name for h in hs_s])

    big = MVDRAMEngine(geom=dataclasses.replace(SPILL_GEOM,
                                                subarrays_per_bank=4))
    hb = [big.register(f"l{i}", w, QuantSpec(bits=4),
                       a_spec=QuantSpec(bits=4)) for i, w in enumerate(ws)]
    prog_b = big.compile([h.name for h in hb])

    Xs = [jnp.asarray(rng.normal(size=(16,)), jnp.float32) for _ in ws]
    ledger_before = pool.spill_restaged_bits
    outs_s, rep_s = prog_s.run(Xs)
    outs_b, _ = prog_b.run(Xs)
    for o1, o2 in zip(outs_b, outs_s):
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    assert rep_s.spill_restages >= SPILL_LAYERS - 2  # the cold set paged
    # the run's bill IS the pool ledger delta
    assert pool.spill_restaged_bits - ledger_before \
        == rep_s.spill_restage_bits

    cost_s = prog_s.price(batch=1, executed=rep_s)
    # EXACT reconciliation: the priced restage term is the CXL model's
    # price of precisely the bits/restages the step paid
    assert cost_s.t_spill_restage == eng_s.cxl.restage_time(
        rep_s.spill_restage_bits, rep_s.spill_restages)
    assert cost_s.spill_restage_bits == rep_s.spill_restage_bits
    overhead = cost_s.t_total / (cost_s.t_total - cost_s.t_spill_restage)
    assert overhead > 1.0
    emit("sim.fabric_spill_restage_overhead_x", overhead,
         "priced decode t_total / resident-only t_total (CXL page-ins "
         "reconciled exactly)")


if __name__ == "__main__":
    def _emit(name, value, derived=""):
        v = f"{value:.6g}" if isinstance(value, float) else value
        print(f"{name},{v},{derived}")
    sim_fabric(_emit)

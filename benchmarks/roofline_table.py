"""§Roofline: aggregate the dry-run JSON records into the per-(arch × shape
× mesh) table (markdown + CSV emission)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_records():
    recs = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        try:
            recs.append(json.load(open(f)))
        except Exception:
            pass
    return recs


def markdown_table(mesh: str = "16x16") -> str:
    rows = ["| arch | shape | peak GiB | compute s | memory s | collective s"
            " | bottleneck | MFLOPs ratio | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in load_records():
        if r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']}{' (fsdp)' if r.get('fsdp') else ''}"
            f" | {r['memory']['peak_bytes_estimate']/2**30:.2f}"
            f" | {rf['compute_s']:.3g} | {rf['memory_s']:.3g}"
            f" | {rf['collective_s']:.3g} | {rf['bottleneck']}"
            f" | {rf['useful_flops_ratio']:.3f}"
            f" | {rf['roofline_fraction']:.4f} |")
    return "\n".join(rows)


def roofline_rows(emit):
    for r in load_records():
        rf = r["roofline"]
        key = f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}"
        emit(f"{key}.bound_s", rf["bound_s"],
             f"bottleneck={rf['bottleneck']}")
        emit(f"{key}.fraction", rf["roofline_fraction"])


ALL = [roofline_rows]

if __name__ == "__main__":
    print(markdown_table("16x16"))
    print()
    print(markdown_table("2x16x16"))

"""Benchmark harness entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only substring]

Prints ``name,value,derived`` CSV. PUD-side numbers come from the calibrated
DDR4-2400 command model (this container has no FPGA testbed); kernel/serve
numbers are measured CPU wall-clock (relative); roofline rows aggregate the
multi-pod dry-run artifacts if present.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import kernel_bench, paper_figs, roofline_table, sim_bench
    benches = (paper_figs.ALL + kernel_bench.ALL + sim_bench.ALL
               + roofline_table.ALL)

    print("name,value,derived")

    def emit(name, value, derived=""):
        if isinstance(value, float):
            value = f"{value:.6g}"
        print(f"{name},{value},{derived}")
        sys.stdout.flush()

    for fn in benches:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            fn(emit)
        except Exception as e:  # noqa: BLE001 — report and continue
            emit(f"{fn.__name__}.ERROR", 0, repr(e)[:200])


if __name__ == "__main__":
    main()

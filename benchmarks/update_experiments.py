"""Render §Dry-run / §Roofline / §Perf into EXPERIMENTS.md from the JSON
records (idempotent — replaces the marker sections)."""
from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(__file__)
EXP = os.path.join(HERE, "..", "EXPERIMENTS.md")


def load(pattern):
    out = []
    for f in sorted(glob.glob(os.path.join(HERE, "results", pattern))):
        with open(f) as fh:
            out.append((os.path.basename(f), json.load(fh)))
    return out


def dryrun_summary():
    recs = [r for _, r in load("dryrun/*.json")]
    lines = ["", "Fit + bottleneck per cell (both meshes):", ""]
    lines += ["| arch | shape | cfg | 16×16 peak GiB | 2×16×16 peak GiB | "
              "bottleneck | lower+compile (s) |",
              "|---|---|---|---|---|---|---|"]
    by = {}
    for r in recs:
        by.setdefault((r["arch"], r["shape"]), {})[r["mesh"]] = r
    for (arch, shape), m in sorted(by.items()):
        s, d = m.get("16x16"), m.get("2x16x16")
        if not s or not d:
            continue
        cfgbits = []
        if s.get("fsdp"):
            cfgbits.append("fsdp")
        if s["remat"]:
            cfgbits.append(f"remat,mb{s['microbatches']}")
        lines.append(
            f"| {arch} | {shape} | {'+'.join(cfgbits) or 'base'} "
            f"| {s['memory']['peak_bytes_estimate']/2**30:.2f} "
            f"| {d['memory']['peak_bytes_estimate']/2**30:.2f} "
            f"| {s['roofline']['bottleneck']} "
            f"| {s['lower_s']+s['compile_s']:.1f} / "
            f"{d['lower_s']+d['compile_s']:.1f} |")
    n = len(by)
    lines.append("")
    lines.append(f"{n} cells × 2 meshes — **all 2·{n} compile; every cell "
                 "fits 16 GiB/device**. Skipped long_500k (full attention): "
                 "deepseek-v2-lite-16b, qwen2-moe-a2.7b, starcoder2-3b, "
                 "qwen2-7b, musicgen-medium, pixtral-12b.")
    return "\n".join(lines)


def roofline_tables():
    recs = [r for _, r in load("dryrun/*.json")]
    out = []
    for mesh, title in (("16x16", "Single pod (256 chips)"),
                        ("2x16x16", "Multi-pod (512 chips)")):
        out.append(f"\n### {title}\n")
        out.append("| arch | shape | compute s | memory s | collective s | "
                   "bottleneck | useful-FLOPs ratio | roofline frac | one-line fix |")
        out.append("|---|---|---|---|---|---|---|---|---|")
        for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
            if r["mesh"] != mesh:
                continue
            rf = r["roofline"]
            fix = {
                "memory": "cut bytes: quantized weights/KV, fused attention",
                "collective": "sequence-parallel residuals; bf16 collectives",
                "compute": "larger per-chip batch",
            }[rf["bottleneck"]]
            out.append(
                f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3g} "
                f"| {rf['memory_s']:.3g} | {rf['collective_s']:.3g} "
                f"| {rf['bottleneck']} | {rf['useful_flops_ratio']:.3f} "
                f"| {rf['roofline_fraction']:.4f} | {fix} |")
    return "\n".join(out)


def technique_coverage():
    rows = ["| arch | baseline mem s | q4+int8KV mem s | gain | peak GiB | notes |",
            "|---|---|---|---|---|---|"]
    for name, t in load("perf_tech/*.json"):
        base_f = os.path.join(HERE, "results", "dryrun",
                              f"{t['arch']}.decode_32k.single.json")
        b = json.load(open(base_f))
        bm, tm = b["roofline"]["memory_s"], t["roofline"]["memory_s"]
        note = {"mla_moe": "MLA lora factors stay fp (absorbed path)",
                "moe": "routed experts served bit-plane (E-stacked)",
                "ssm": "SSD recurrence stays fp (technique N/A there)",
                "hybrid": "mamba projections + shared attn quantized"}.get(
                    "", "")
        rows.append(
            f"| {t['arch']} | {bm:.4f} | {tm:.4f} | {bm/tm:.2f}× "
            f"| {b['memory']['peak_bytes_estimate']/2**30:.2f} → "
            f"{t['memory']['peak_bytes_estimate']/2**30:.2f} | |")
    return "\n".join(rows)


def perf_log():
    recs = dict((n[:-5], r) for n, r in load("perf/*.json"))

    def row(key):
        r = recs[key]
        rf = r["roofline"]
        return (f"bound {rf['bound_s']:.3g}s ({rf['bottleneck']}); "
                f"mem {rf['memory_s']:.3g} / coll {rf['collective_s']:.3g} / "
                f"comp {rf['compute_s']:.3g}; frac "
                f"{rf['roofline_fraction']:.4f}; peak "
                f"{r['memory']['peak_bytes_estimate']/2**30:.2f} GiB")

    out = PERF_TEMPLATE.format(**{k.replace(".", "_"): row(k)
                                  for k in recs})
    return out + TECH_TEMPLATE.format(table=technique_coverage())


PERF_TEMPLATE = """
Methodology: hypothesis → change → re-lower/re-analyse → confirm/refute,
per cell, on the dominant roofline term; stop after consecutive <5% moves.
All numbers from the single-pod dry-run artifacts
(benchmarks/results/perf/*.json).

### Cell C — qwen2-7b × decode_32k (paper-representative: low-bit GeMV decode)

| iter | change | result | verdict |
|---|---|---|---|
| C0 | naive: KV replicated over model axis | {C_kv_replicated} | baseline does not even fit |
| C1 | **kv_seq→model** (flash-decoding seq-sharded cache). Hypothesis: cache is 15/16 redundant → memory ≫10× down | {C_baseline} | CONFIRMED (9.9× on bound; collective ÷707) — adopted as table baseline |
| C2 | **int8 KV cache** (+ per-token/head scales). Hypothesis: cache reads ≈ half of remaining traffic → ~1.5× | {C_kv_int8} | CONFIRMED 1.69× |
| C3 | **paper technique: 4-bit bit-plane weights** (quantize_defs → packed planes). Hypothesis: weight bytes ÷4 → ~1.5× | {C_bitplane_q4} | PARTIAL: 1.15× — at XLA level the jnp unpack (planes→f32) writes back ~0.475 GB/layer-group; the capacity win is full (peak 5.66→3.64 GiB) |
| C4 | C2+C3 combined | {C_bitplane_q4_kv8} | CONFIRMED 2.18× vs C1; peak 1.49 GiB (3.8× headroom for batch growth — the paper's "DRAM as dual-use asset" at HBM scale) |

Kernel-level projection (the TPU path, validated in interpret mode with
BlockSpec (bn=512, bm=256) tiling — tests/test_kernels.py): the Pallas
bitplane kernel unpacks INSIDE VMEM, so HBM weight traffic is the packed
planes (q/16 of bf16); the int8-KV dequant likewise fuses into a decode
attention kernel. Projected per-step traffic ≈ 0.24 GB (planes) + 0.47 GB
(int8 cache) + 0.15 GB (activations) ≈ 0.9 GB/device → memory term ≈ 1.1 ms,
i.e. **≈18× over the C1 baseline**; measured XLA-level result is the
conservative 2.18×. Top-writes attribution for C4 shows exactly the two
fusable converts as the residual — which is what kernels/decode_attention
(flash-decode with int8 dequant fused in VMEM, validated in
tests/test_decode_kernel.py) plus the bitplane kernel eliminate on TPU.

### Cell A — zamba2-7b × train_4k (most collective-bound)

| iter | change | result | verdict |
|---|---|---|---|
| A0 | baseline (remat, mb=8) | {A_baseline} | collective-bound: 81 mamba out-proj all-reduces/microbatch dominate |
| A1 | **sequence parallelism** (residual stream seq→model; AR → RS+AG on a 16× smaller live tensor) | {A_seqpar} | CONFIRMED: collective 8.63→1.45 s (−5.9×); now memory-bound; +1.5× frac but peak 17.3 GiB (over) |
| A2 | fewer microbatches (mb=4): halve per-step scan overheads | {A_mb4} | REFUTED for collectives (unchanged — they scale with tokens, not microbatches); memory flat |
| A3 | seqpar + mb4 | {A_seqpar_mb4} | best bound (4.50 s) but 21.6 GiB — over HBM |
| A4 | **seqpar + FSDP** (params+opt over data) | {A_fsdp_seqpar} | fits (8.55 GiB) at 5.93 s |
| A5 | seqpar + FSDP + mb4 | {A_fsdp_seqpar_mb4} | **adopted**: frac 0.101 → 0.187 (1.86×), fits (12.8 GiB) |
| A6 | SSD chunk 256→128. Hypothesis: intra-chunk decay tiles (∝ H·Q per token) dominate the SSD traffic → halving Q wins | {A_fsdp_seqpar_mb4_ssd128} | REFUTED: −14% — the inter-chunk carry materializations (∝ nc = L/Q scan steps) outweigh the tile saving at zamba2's H=112 |
| A7 | SSD chunk 256→64 (confirm the trend) | {A_fsdp_seqpar_mb4_ssd64} | REFUTED: −43% — confirms A6's lesson; chunk 256 sits near the tile-vs-carry optimum |

Cell A converged (A2, A6, A7 refuted); A5 stands at **1.86× over baseline,
bottleneck flipped collective → memory**.

### Cell B — musicgen-medium × train_4k (worst train roofline fraction)

| iter | change | result | verdict |
|---|---|---|---|
| B0 | baseline (remat, mb=8) | {B_baseline} | memory-bound; small d_model ⇒ attention tiles dominate |
| B1 | mb=2 (fewer param re-reads) | {B_mb2} | REFUTED: −0.4% — traffic ∝ tokens, not microbatch count; peak ×2.9 |
| B2 | mb=2, NO remat | {B_mb2_norem} | REFUTED decisively: 247 GiB — remat is mandatory at 1M-token batch |
| B3 | **sequence parallelism** (mb=2) | {B_seqpar_mb2} | CONFIRMED 2.01×: frac 0.035 → 0.071; attention tiles were 16×-replicated because 24 heads don't divide the model axis — seq-sharding distributes them |
| B4 | bf16 flash score/p tiles | {B_seqpar_mb2_bf16flash} | REFUTED: +9% — the extra f32→bf16 p cast materializes one MORE tile per block at XLA level (a fused kernel keeps it in registers; lesson recorded) |
| B5 | flash block 1024→2048 | {B_seqpar_mb2_bf16flash_blk2k} | REFUTED: 0% — total tile bytes are block-size invariant |

Converged by the <5%-three-times rule (B1, B4, B5). Top-writes attribution:
~930 GB/step of the remaining 1960 GB are flash score-tile materializations
(≈12 f32[8,24,256,1024] tensors per KV-block step, forward+backward) — all
VMEM-resident in a fused splash-attention Pallas kernel; projected memory
term without them ≈ 1.26 s → frac ≈ 0.135 (3.8× over B0).

### Paper-faithful vs beyond-paper (summary)

* Paper-faithful serving baseline (C1 + 4-bit bit-plane weights = the
  paper's deployment, C3): 1.18× measured at XLA level, full capacity win,
  ≈15× with the Pallas kernel the TPU actually runs.
* Beyond-paper additions measured here: sequence-sharded KV (11.2×),
  int8 KV cache (1.42×), sequence-parallel training (5.9× on collectives),
  FSDP fit, strided static microbatching (fixed a 20 GiB SPMD all-gather).
"""


def inject(md, marker, content):
    """Idempotent: replaces everything between the marker and the next
    top-level heading (or EOF) with the freshly rendered content."""
    tag = f"<!-- {marker} -->"
    if tag not in md:
        return md
    start = md.index(tag) + len(tag)
    nxt = md.find("\n## ", start)
    tail = md[nxt:] if nxt != -1 else ""
    return md[:start] + "\n" + content.rstrip() + "\n" + tail


def main():
    with open(EXP) as f:
        md = f.read()
    md = inject(md, "DRYRUN_SUMMARY", dryrun_summary())
    md = inject(md, "ROOFLINE_TABLES", roofline_tables())
    md = inject(md, "PERF_LOG", perf_log())
    with open(EXP, "w") as f:
        f.write(md)
    print("EXPERIMENTS.md updated")





TECH_TEMPLATE = """

### Technique coverage — the paper's serving point on EVERY assigned arch

decode_32k, single pod: bf16 baseline vs 4-bit bit-plane weights + int8 KV
cache (benchmarks/results/perf_tech/*.json). Gains are the conservative
XLA-level memory-term ratios; the Pallas kernels (bitplane_gemv +
decode_attention, both interpret-validated) remove the residual unpack /
dequant materializations on real TPUs. The peak column is the paper's
capacity story at HBM scale: 3–6× headroom for batch/context growth.

{table}

Arch-applicability notes: deepseek MLA keeps its low-rank W_uk/W_uv factors
in fp (the absorbed decode path contracts them per-head, and they are ~1M
params/layer); SSM/hybrid recurrences stay fp (no GeMV shape — DESIGN.md
§Arch-applicability); MoE routed experts ARE quantized (E-stacked planes,
vmap'd bit-plane GeMV per expert).
"""


if __name__ == "__main__":
    main()

"""Serve-traffic benchmark: Poisson arrivals over the resident decode program.

A quantized `ContinuousBatcher` (8 lanes, chunked prefill) serves a seeded
Poisson arrival process of 300 requests with random prompt/generation
lengths — an open-loop load chosen ABOVE the service rate so the backlog
climbs into the hundreds before draining (long horizon, hundreds of
requests in flight in the system). Every inner decode step is one
execution of the engine's capacity `GemvProgram` at that step's lane
occupancy; the clock the latency percentiles are measured on is the
PRICED DDR4 clock those masked program ticks advance (`sim_time_s`), not
host wall-clock.

Rows (latency/throughput, not speedups — require-rows-guarded only, like
the PR 6 fault rows):

    sim.serve_tokens_per_s   generated tokens per priced second
    sim.serve_p50_ms         median request latency (arrival → last token)
    sim.serve_p99_ms         tail request latency

Internal hard asserts: every request finishes with stamps ordered
arrival ≤ first-token ≤ finish; the whole horizon is served by ONE
compiled capacity program — zero recompilation, zero re-staging (fused
plan object identity across the run) and a bounded tick-executable set;
and on a capped sample of the occupancy masks the traffic actually
produced, a REAL masked `GemvProgram.run(lane_mask=…)` is re-executed
and must be bit-identical per active lane to a freshly compiled
compacted fixed-B oracle, with `price_program(executed=…)` reconciling —
the priced clock the percentiles sit on is the price of executions the
simulator demonstrably performs.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

LANES = 8
N_REQUESTS = 300
ARRIVAL_RATE_HZ = 60.0      # ~2x the measured service rate: backlog builds
MAX_SEQ = 32
VERIFY_MASKS = 3            # capped real masked-program executions


def _poisson_requests(cfg, rng):
    from repro.serve.scheduler import Request

    t, reqs = 0.0, []
    for i in range(N_REQUESTS):
        t += float(rng.exponential(1.0 / ARRIVAL_RATE_HZ))
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(3, 10))).tolist()
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new=int(rng.integers(2, 8)),
                            arrival_s=t))
    return reqs


def _capacity_view(batcher):
    """A capacity program over the decode program's SIM-RUNNABLE layers
    (quantized activations — float-activation layers like the lm_head run
    on the host and have no bit-serial stream to mask), reusing the SAME
    resident placements and concurrency groups. Nothing is re-staged:
    compile only indexes rows the serve engine already placed."""
    prog = batcher.engine.decode_program
    keep = [i for i, h in enumerate(prog.handles) if h.a_spec is not None]
    remap = {old: new for new, old in enumerate(keep)}
    groups = [[remap[i] for i in g if i in remap] for g in prog.groups]
    groups = [g for g in groups if g]
    names = [prog.handles[i].name for i in keep]
    return batcher.engine.mvdram.compile(names, groups=groups,
                                         b_max=prog.b_max), groups


def _program_inputs(prog, rng):
    return [jnp.asarray(rng.normal(size=(prog.b_max, h.plan.n)),
                        jnp.float32) for h in prog.handles]


def _verify_masked_program(batcher, prog, masks, X):
    """Re-execute the engine's capacity program at a sample of the
    occupancy masks the traffic produced, against a freshly compiled
    compacted fixed-B oracle over the SAME resident placements: active
    lanes bit-identical (outputs and per-tile OpCounts), masked lanes
    zero, and the executed-wave price at that occupancy reconciling
    exactly. This pins the priced clock to executions the simulator
    actually performs."""
    mvdram = batcher.engine.mvdram
    oracle = mvdram.compile([h.name for h in prog.handles],
                            groups=[list(g) for g in prog.groups])
    for mask in masks:
        mask = np.asarray(mask, bool)
        outs_m, rep_m = prog.run(X, lane_mask=mask)
        outs_c, rep_c = oracle.run([x[mask] for x in X])
        occ = int(mask.sum())
        assert rep_m.batch == occ and rep_m.lanes == prog.b_max
        for l, (om, oc) in enumerate(zip(outs_m, outs_c)):
            om, oc = np.asarray(om), np.asarray(oc)
            assert np.array_equal(om[mask], oc), \
                f"masked layer {l} diverged from the compacted oracle"
            assert (om[~mask] == 0).all(), f"masked layer {l} leaked rows"
        for rm, rc in zip(rep_m.reports, rep_c.reports):
            act = [r for r, keep in zip(rm.requests, mask) if keep]
            assert all(
                [c.asdict() for c in ra.tile_runtime]
                == [c.asdict() for c in rb.tile_runtime]
                for ra, rb in zip(act, rc.requests)), \
                "active-lane OpCounts diverged"
            assert rm.runtime.asdict() == rc.runtime.asdict()
        assert rep_m.executed_wave_ops == rep_c.executed_wave_ops
        cost_m = mvdram.price_program(prog, batch=occ, executed=rep_m)
        cost_c = mvdram.price_program(oracle, batch=occ, executed=rep_c)
        assert cost_m.asdict() == cost_c.asdict(), \
            "masked-occupancy price failed to reconcile with the oracle"
    return len(masks)


def sim_serve_traffic(emit):
    from repro.configs import tiny_config
    from repro.models.model import param_defs
    from repro.models.params import init_params
    from repro.serve.scheduler import ContinuousBatcher

    cfg = dataclasses.replace(tiny_config("llama2-7b"), dtype="float32")
    params = init_params(param_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(47)
    reqs = _poisson_requests(cfg, rng)

    b = ContinuousBatcher(cfg, params, max_seq=MAX_SEQ, lanes=LANES,
                          quantized=True, act_bits=4, prefill_chunk=8)
    # the serve engine's decode program prices the ticks; the sim-runnable
    # capacity view over the same resident placements is what the masked
    # verification executes. Build its fused plan ONCE, at full occupancy,
    # before the horizon — every later execution must reuse this object.
    prog = b.engine.decode_program
    vprog, _vgroups = _capacity_view(b)
    X = _program_inputs(vprog, rng)
    _outs0, rep0 = vprog.run(X)
    fused_id = id(vprog._fused)
    assert rep0.repeated_staging.host_bits_written == 0, \
        "resident program re-staged weights on a decode step"
    seen_masks: dict = {}
    peak_backlog = 0
    t_wall = time.perf_counter()
    i = 0
    while i < len(reqs) or b.pending or b.in_flight:
        while i < len(reqs) and reqs[i].arrival_s <= b.sim_time_s:
            b.submit(reqs[i])
            i += 1
        peak_backlog = max(peak_backlog, b.pending + b.in_flight)
        if b.pending == 0 and b.in_flight == 0:
            # open-loop idle: fast-forward the priced clock to the next
            # arrival (no program tick executes, so no cost accrues)
            b.sim_time_s = max(b.sim_time_s, reqs[i].arrival_s)
            continue
        for m in b.tick_masks():
            occ = int(m.sum())
            if 0 < occ < LANES and occ not in seen_masks:
                seen_masks[occ] = tuple(bool(x) for x in m)
        b.tick()
    t_wall = time.perf_counter() - t_wall

    done = b.finished
    assert len(done) == N_REQUESTS, \
        f"traffic horizon starved: {len(done)}/{N_REQUESTS} finished"
    assert all(r.done for r in done)
    lat = np.array([r.finish_s - r.arrival_s for r in done])
    ttft = np.array([r.first_token_s - r.arrival_s for r in done])
    assert (ttft >= 0).all() and (lat >= ttft).all(), \
        "request stamps out of order (arrival <= first token <= finish)"
    assert peak_backlog >= 100, \
        f"load too light for a traffic bench: peak backlog {peak_backlog}"

    # ONE compiled capacity program served every occupancy on the horizon:
    # zero recompilation, bounded tick-executable set
    assert prog is b.engine.decode_program
    assert prog.b_max == LANES and vprog.b_max == LANES
    assert len(b._tick_fns) <= 4, \
        f"tick executables unbounded: {len(b._tick_fns)}"
    assert b.sim_time_s > 0.0 and b.tokens_out > 0

    # capped REAL masked executions at observed occupancies vs the
    # compacted oracle (bit-exact + price reconciliation)
    verify = sorted(seen_masks.values(),
                    key=lambda m: sum(m))[:VERIFY_MASKS]
    verified = _verify_masked_program(b, vprog, verify, X)
    assert id(vprog._fused) == fused_id, \
        "occupancy churn re-staged the fused plan mid-horizon"

    occ_hist = dict(sorted(b.occupancy_ticks.items()))
    tput = b.tokens_out / b.sim_time_s
    emit("sim.serve_tokens_per_s", tput,
         f"poisson {ARRIVAL_RATE_HZ:g}req/s x{N_REQUESTS} lanes={LANES} "
         f"program_ticks={b.program_ticks} peak_backlog={peak_backlog} "
         f"occ={occ_hist} verified_masks={verified} wall_s={t_wall:.1f}")
    emit("sim.serve_p50_ms", float(np.percentile(lat, 50)) * 1e3,
         f"priced-clock request latency, n={len(done)} "
         f"ttft_p50_ms={np.percentile(ttft, 50) * 1e3:.1f}")
    emit("sim.serve_p99_ms", float(np.percentile(lat, 99)) * 1e3,
         f"tail over {len(done)} requests, horizon={b.sim_time_s:.1f}s")

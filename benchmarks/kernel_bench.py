"""Kernel micro-benchmarks (CPU wall-clock — RELATIVE numbers only; the
TPU path is priced by the dry-run roofline) + a large-shape correctness
check of the interpret-mode kernel against the oracle."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitplane import make_bitplane_weights
from repro.core.quant import QuantSpec, quantize_weights
from repro.kernels.bitplane_gemv import ops as bp
from repro.kernels.quant_matmul import ops as qm


def _time(fn, *args, n=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6   # µs


def kernel_microbench(emit):
    rng = np.random.default_rng(0)
    n, m, b = 4096, 4096, 4
    w = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    dense_w = w.astype(jnp.bfloat16)
    dense = jax.jit(lambda x: (x.astype(jnp.bfloat16) @ dense_w
                               ).astype(jnp.float32))
    emit("kernel.dense_bf16_us", _time(dense, a))
    for q in (2, 4):
        bw = make_bitplane_weights(w, QuantSpec(bits=q))
        f = jax.jit(lambda x, bw=bw: bp.bitplane_gemv(x, bw, impl="jnp"))
        emit(f"kernel.bitplane_q{q}_jnp_us", _time(f, a),
             f"packed bytes={int(bw.planes.size * 4)}")
        wq = quantize_weights(w, QuantSpec(bits=q))
        g = jax.jit(lambda x, wq=wq: qm.quant_matmul(x, wq, impl="jnp"))
        emit(f"kernel.quant_matmul_q{q}_jnp_us", _time(g, a))
    # interpret-mode kernel correctness at a production-ish shape
    bw = make_bitplane_weights(w[:, :512], QuantSpec(bits=4))
    ref = bp.bitplane_gemv(a, bw, impl="jnp")
    got = bp.bitplane_gemv(a, bw, impl="pallas_interpret")
    err = float(jnp.abs(ref - got).max() / (jnp.abs(ref).max() + 1e-9))
    emit("kernel.interpret_vs_oracle_relerr", err, "must be ~1e-6")
    assert err < 1e-4


def serve_relative_bench(emit):
    """Measured decode throughput, dense bf16 vs bit-plane-served weights
    (tiny model, CPU): demonstrates the end-to-end serving path."""
    import dataclasses
    from repro.configs import tiny_config
    from repro.models.model import param_defs
    from repro.models.params import init_params
    from repro.serve.engine import ServeEngine
    cfg = dataclasses.replace(tiny_config("llama2-7b"), weight_bits=2)
    params = init_params(param_defs(cfg), jax.random.PRNGKey(0))
    for tag, quantized in (("dense", False), ("bitplane_q2", True)):
        eng = ServeEngine(cfg, params, max_seq=64, quantized=quantized)
        emit(f"serve.{tag}.tok_s",
             eng.throughput_tokens_per_s(b=2, n=16))


ALL = [kernel_microbench, serve_relative_bench]

"""Baseline dry-run sweep driver: every live (arch × shape) cell on the
single-pod (16×16) and multi-pod (2×16×16) meshes.

Each cell runs in its own subprocess (dryrun.py must own jax init to force
512 host devices). Train cells run with layer remat + 8 microbatches (the
production memory configuration at 1M-token global batch). If a cell's
peak-per-device estimate exceeds v5e HBM (16 GiB), it is re-run with the
FSDP rule set (params+optimizer sharded over the data axis, ZeRO-3 style)
and recorded as such — that *is* the deployable baseline for those cells.

Usage:  PYTHONPATH=src python -m benchmarks.dryrun_sweep [--mesh single|multi|both]
Results: benchmarks/results/dryrun/{arch}.{shape}.{mesh}.json (+ sweep.log)
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

HBM = 16 * 2 ** 30
FSDP_RULES = '{"embed": "data", "expert_mlp": "data", "lora": "data"}'
OUT_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def run_one(arch: str, shape: str, mesh: str, kind: str, log) -> dict:
    out = os.path.join(OUT_DIR, f"{arch}.{shape}.{mesh}.json")
    if os.path.exists(out):
        rec = json.load(open(out))
        log(f"SKIP {arch} {shape} {mesh} (cached)")
        return rec
    base = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
            "--shape", shape, "--mesh", mesh, "--out", out]
    if kind == "train":
        base += ["--remat", "--microbatches", "8"]

    def attempt(extra, tag):
        t0 = time.time()
        r = subprocess.run(base + extra, capture_output=True, text=True,
                           timeout=1800)
        dt = time.time() - t0
        if r.returncode != 0:
            log(f"FAIL {arch} {shape} {mesh} {tag} ({dt:.0f}s): "
                f"{r.stderr.strip().splitlines()[-1][:300] if r.stderr else '?'}")
            return None
        rec = json.load(open(out))
        peak = rec["memory"]["peak_bytes_estimate"]
        log(f"OK   {arch} {shape} {mesh} {tag} ({dt:.0f}s) "
            f"peak={peak/2**30:.2f}GiB bottleneck="
            f"{rec['roofline']['bottleneck']} "
            f"frac={rec['roofline']['roofline_fraction']:.4f}")
        return rec

    rec = attempt([], "base")
    if rec and rec["memory"]["peak_bytes_estimate"] > HBM:
        os.rename(out, out + ".nofsdp")
        rec2 = attempt(["--rules", FSDP_RULES], "fsdp")
        if rec2 is not None:
            rec2["fsdp"] = True
            json.dump(rec2, open(out, "w"), indent=1)
            return rec2
        os.rename(out + ".nofsdp", out)
    return rec


def main():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.configs import SHAPES, cells
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--only", default=None, help="substring filter arch")
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)
    logf = open(os.path.join(OUT_DIR, "sweep.log"), "a")

    def log(msg):
        line = f"[{time.strftime('%H:%M:%S')}] {msg}"
        print(line, flush=True)
        logf.write(line + "\n")
        logf.flush()

    live, skipped = cells()
    for a, s in skipped:
        log(f"SKIPCELL {a} {s} (long_500k: full attention)")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mesh in meshes:
        for a, s in live:
            if args.only and args.only not in a:
                continue
            try:
                run_one(a, s, mesh, SHAPES[s].kind, log)
            except subprocess.TimeoutExpired:
                log(f"TIMEOUT {a} {s} {mesh}")
            except Exception as e:  # noqa: BLE001 — keep sweeping
                log(f"ERROR {a} {s} {mesh}: {e}")
    log("sweep complete")


if __name__ == "__main__":
    main()

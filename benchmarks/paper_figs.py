"""One benchmark per paper table/figure (Figs. 3, 12–17 + Table I effects).

The PUD side is the calibrated command-level model (core/pud/timing.py; the
container has no DDR4+FPGA testbed — see DESIGN.md §2); the CPU/GPU sides
are the analytic baselines calibrated to Table II / Fig. 12 anchors. Every
row prints model output next to the paper's claim where one exists.
"""
from __future__ import annotations

import numpy as np

from repro.core.pud.gemv import (PudGeometry, conventional_pud_cost,
                                 mvdram_gemv_cost, usable_output_slots)
from repro.core.pud.layout import horizontal_capacity_report
from repro.core.pud.timing import (CpuBaseline, DDR4_2400, GpuBaseline,
                                   compare_gemv, price_gemv)

GEOM = PudGeometry()


def fig3_latency_profile(emit):
    """Fig. 3: 32768×8192 4-bit GeMV — where the time goes, conventional PUD
    vs MVDRAM (pre-arrange / compute / aggregate+transpose)."""
    m, n, q, p = 32768, 8192, 4, 4
    mv = price_gemv(mvdram_gemv_cost(m, n, q, p), GEOM)
    conv = price_gemv(conventional_pud_cost(m, n, q, p), GEOM)
    cpu = CpuBaseline().gemv_time(m, n, q, p)
    emit("fig3.conventional.prearrange_ms", conv.t_prearrange * 1e3)
    emit("fig3.conventional.compute_ms", conv.t_compute * 1e3)
    emit("fig3.conventional.aggregate_ms", conv.t_aggregate * 1e3)
    emit("fig3.conventional.total_ms", conv.t_total * 1e3)
    emit("fig3.mvdram.prearrange_ms", mv.t_prearrange * 1e3,
         "on-the-fly encoding: 0 by construction")
    emit("fig3.mvdram.compute_ms", mv.t_compute * 1e3)
    emit("fig3.mvdram.aggregate_ms", mv.t_aggregate * 1e3,
         "no bit-transposition (horizontal layout)")
    emit("fig3.mvdram.total_ms", mv.t_total * 1e3)
    emit("fig3.cpu.total_ms", cpu * 1e3)


def fig12_gemv_bitwidth(emit):
    """Fig. 12: 32000×4096 GeMV latency across weight bit-widths."""
    r = compare_gemv(32000, 4096, q=2, p=1)
    emit("fig12.q2_p1.mvdram_ms", r["mvdram_ms"], "paper: 0.19")
    emit("fig12.q2_p1.cpu_ms", r["cpu_ms"], "paper: 1.44")
    emit("fig12.q2_p1.gpu_ms", r["gpu_ms"], "paper: 1.70")
    emit("fig12.q2_p1.speedup_cpu", r["speedup_vs_cpu"], "paper: 7.29x")
    for q in (2, 3, 4, 8):
        rr = compare_gemv(32000, 4096, q=q, p=4)
        emit(f"fig12.q{q}_p4.mvdram_ms", rr["mvdram_ms"])
        emit(f"fig12.q{q}_p4.speedup_cpu", rr["speedup_vs_cpu"])


def fig13_gemv_size(emit):
    """Fig. 13: square GeMV latency across sizes at 2-bit weights."""
    for sz in (2048, 4096, 8192, 16384, 32768):
        r = compare_gemv(sz, sz, q=2, p=4)
        note = "paper: 3.38x cpu / 3.74x gpu" if sz == 32768 else ""
        emit(f"fig13.{sz}.mvdram_ms", r["mvdram_ms"])
        emit(f"fig13.{sz}.speedup_cpu", r["speedup_vs_cpu"], note)


def fig14_energy(emit):
    """Fig. 14: 32000×4096 GeMV energy, 2-bit matrix, vector width sweep."""
    for p, note in [(1, "paper: 30.5x cpu / 8.87x gpu"), (2, ""), (4, ""),
                    (8, "")]:
        r = compare_gemv(32000, 4096, q=2, p=p)
        emit(f"fig14.p{p}.mvdram_mj", r["mvdram_mj"])
        emit(f"fig14.p{p}.energy_ratio_cpu", r["energy_ratio_vs_cpu"], note)
        emit(f"fig14.p{p}.energy_ratio_gpu", r["energy_ratio_vs_gpu"])


def fig15_capacity(emit):
    """Fig. 15: subarray row-utilization breakdown for 4-bit GeMV."""
    for n_sub in (32, 64, 128):
        rep = horizontal_capacity_report(n_sub=n_sub, q=4, p=4)
        emit(f"fig15.n{n_sub}.matrix_rows", rep["matrix_rows"]
             + rep["inverted_matrix_rows"])
        emit(f"fig15.n{n_sub}.compute_output_rows",
             rep["computation_rows"] + rep["output_rows"])
        emit(f"fig15.n{n_sub}.overhead_fraction", rep["overhead_fraction"],
             "paper: minimal vs matrix storage")


def table1_reliable_columns(emit):
    """Table I: usable output slots under measured reliable-column counts."""
    rng = np.random.default_rng(0)
    for name, reliable in [("module1", 61727), ("module3", 54365)]:
        mask = np.ones(65536, bool)
        bad = rng.choice(65536, 65536 - reliable, replace=False)
        mask[bad] = False
        for q in (2, 4):
            slots = usable_output_slots(mask, q)
            emit(f"table1.{name}.q{q}.outputs_per_subarray", len(slots),
                 f"{reliable}/65536 reliable columns")


# -- end-to-end token throughput/energy (Figs. 16/17) -------------------------

E2E_MODELS = {
    # name: (layers, d_model, n_heads, d_ff, vocab)
    "llama2-7b": (32, 4096, 32, 11008, 32000),
    "llama2-13b": (40, 5120, 40, 13824, 32000),
    "llama3-8b": (32, 4096, 32, 14336, 128256),
    "phi-4": (40, 5120, 40, 17920, 100352),
}
T_OTHER = 9.0e-3   # s/token of non-GeMV work (attention·KV, norms, sampling)
HOST_W = 12.0      # host package watts during the non-GeMV phase
HOST_IDLE_W = 30.0  # host idles (but stays powered) while DRAM computes —
#                     excluded from the isolated-GeMV Fig. 14 numbers, real
#                     in the end-to-end pipeline

# The paper does not state the ACTIVATION precision of its llama.cpp
# integration. Our calibrated model brackets the claimed end-to-end ratios
# between p=1 (sign-bit activations; ratio above paper) and p=2 (below);
# microbenchmark anchors (Figs. 3/12/13/14) all match within tolerance —
# recorded in EXPERIMENTS.md §Paper-claims.
E2E_ACT_BITS = (1, 2)


def _gemv_list(model):
    layers, d, h, ff, vocab = E2E_MODELS[model]
    # fused qkv / fused gate+up (same reduction dim ⇒ same command stream)
    return ([(3 * d, d), (d, d), (2 * ff, d), (d, ff)] * layers
            + [(vocab, d)])


def fig16_17_e2e(emit):
    cpu = CpuBaseline()
    for model in E2E_MODELS:
        for q, note_t, note_e in [
                (2, "paper 13b: 2.18x", "paper 13b: 3.04x"),
                (4, "paper 13b: 1.31x", "paper 13b: 2.35x")]:
            t_cpu = sum(cpu.gemv_time(m, n, q, 8)
                        for m, n in _gemv_list(model)) + T_OTHER
            emit(f"fig16.{model}.q{q}.cpu_tok_s", 1.0 / t_cpu)
            for p in E2E_ACT_BITS:
                costs = [price_gemv(mvdram_gemv_cost(m, n, q, p), GEOM)
                         for m, n in _gemv_list(model)]
                t_mv = sum(c.t_total for c in costs) + T_OTHER
                e_mv = (sum(c.e_total for c in costs) + T_OTHER * HOST_W
                        + HOST_IDLE_W * (t_mv - T_OTHER))
                e_cpu = t_cpu * cpu.power
                emit(f"fig16.{model}.q{q}.p{p}.mvdram_tok_s", 1.0 / t_mv)
                emit(f"fig16.{model}.q{q}.p{p}.throughput_ratio",
                     t_cpu / t_mv, note_t if "13b" in model else "")
                emit(f"fig17.{model}.q{q}.p{p}.energy_ratio", e_cpu / e_mv,
                     note_e if "13b" in model else "")


ALL = [fig3_latency_profile, fig12_gemv_bitwidth, fig13_gemv_size,
       fig14_energy, fig15_capacity, table1_reliable_columns, fig16_17_e2e]

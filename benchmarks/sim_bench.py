"""Simulator + kernel-schedule benchmarks for the template architecture.

Measures (1) PUD-simulator GeMV wall-clock, naive micro-op oracle vs the
template-selected vectorized executor, on the paper-representative 512×256
q=4/p=4 shape — asserting the ≥20× acceptance floor and bit-identical
outputs/OpCounts; (2) wave-parallel BankArray dispatch vs the sequential
per-tile template path at banked geometry (256 tiles → 4 waves) — asserting
the ≥5× acceptance floor, bit-identical outputs AND per-tile OpCounts;
(3) cross-request wave sharing: one B=4 batched GeMV launch vs 4 sequential
launches at the same banked geometry — asserting the ≥2× amortization
floor, per-request outputs AND per-tile OpCounts bit-identical to the
sequential oracle, and `price_gemv_batched`'s amortized weight staging
reconciling with the simulator's shared-wave counts; and (4) the MXU dots
issued per tile by the bit-serial Pallas kernel's decomposed schedule vs
the §V-D code-dot fast path (q·p vs q), plus measured interpret-mode
wall-clock for both fidelities.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.bitplane import make_bitplane_weights
from repro.core.pud.gemv import PudGeometry, mvdram_gemv, mvdram_gemv_cost
from repro.core.pud.timing import price_gemv_batched
from repro.core.quant import (QuantSpec, quantize_activations,
                              quantize_weights)
from repro.kernels.bitplane_gemv import ops as bp
from repro.kernels.bitplane_gemv.kernel import dots_per_tile

N, M, Q, P = 512, 256, 4, 4
# Banked geometry for the wave benchmark: 16 reduction chunks × 16 column
# chunks = 256 tiles over 64 concurrent subarrays → 4 waves.
BANKED = PudGeometry(subarray_cols=64, n_sub_max=32)


def _best_of(fn, reps: int = 3):
    best, ret = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        if dt < best:
            best, ret = dt, out
    return best, ret


def sim_vectorized_vs_naive(emit):
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(N, M)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    wq = quantize_weights(w, QuantSpec(bits=Q))
    aq = quantize_activations(a, QuantSpec(bits=P))

    t0 = time.perf_counter()
    out_v, rep_v = mvdram_gemv(aq, wq)
    t_vec = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_n, rep_n = mvdram_gemv(aq, wq, naive=True)
    t_naive = time.perf_counter() - t0

    bit_identical = (np.array_equal(np.asarray(out_v), np.asarray(out_n))
                     and rep_v.runtime.asdict() == rep_n.runtime.asdict())
    speedup = t_naive / t_vec
    emit("sim.naive_512x256_q4p4_ms", t_naive * 1e3)
    emit("sim.vectorized_512x256_q4p4_ms", t_vec * 1e3)
    emit("sim.vectorized_speedup_x", speedup,
         f"bit_identical={bit_identical} pud_ops={rep_v.runtime.pud_ops}")
    assert bit_identical, "vectorized sim diverged from the naive oracle"
    assert speedup >= 20.0, f"speedup {speedup:.1f}x below the 20x floor"


def sim_wave_vs_sequential(emit):
    """Wave-parallel BankArray dispatch vs the sequential template path at
    banked geometry — the §VII channel/bank concurrency win on top of PR 1's
    template vectorization."""
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(size=(N, M)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    wq = quantize_weights(w, QuantSpec(bits=Q))
    aq = quantize_activations(a, QuantSpec(bits=P))

    mvdram_gemv(aq, wq, geom=BANKED)  # warm template/plan caches
    t_wave, (out_w, rep_w) = _best_of(lambda: mvdram_gemv(aq, wq, geom=BANKED))
    t_seq, (out_s, rep_s) = _best_of(
        lambda: mvdram_gemv(aq, wq, geom=BANKED, wave=False))

    bit_identical = (
        np.array_equal(np.asarray(out_w), np.asarray(out_s))
        and [c.asdict() for c in rep_w.tile_runtime]
            == [c.asdict() for c in rep_s.tile_runtime]
        and rep_w.runtime.asdict() == rep_s.runtime.asdict())
    speedup = t_seq / t_wave
    emit("sim.sequential_banked_512x256_q4p4_ms", t_seq * 1e3)
    emit("sim.wave_banked_512x256_q4p4_ms", t_wave * 1e3)
    emit("sim.wave_speedup_x", speedup,
         f"bit_identical={bit_identical} tiles={rep_w.tiles} "
         f"waves={rep_w.waves}")
    assert bit_identical, "wave sim diverged from the sequential oracle"
    assert rep_w.waves == 4, f"expected 4 waves, got {rep_w.waves}"
    assert speedup >= 5.0, f"speedup {speedup:.1f}x below the 5x floor"


def sim_batched_wave_sharing(emit):
    """Cross-request wave sharing: B=4 activation vectors against one
    resident matrix in shared waves vs 4 independent sequential launches.
    The per-wave weight staging happens once for the batch; outputs and
    per-tile OpCounts of every request must be bit-identical to its
    sequential-oracle run, and the analytic `price_gemv_batched` must
    reconcile with the simulator's shared-wave staging counts."""
    B = 4
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(N, M)), jnp.float32)
    A = jnp.asarray(rng.normal(size=(B, N)), jnp.float32)
    wq = quantize_weights(w, QuantSpec(bits=Q))
    aqb = quantize_activations(A, QuantSpec(bits=P))
    aqs = [quantize_activations(A[b], QuantSpec(bits=P)) for b in range(B)]

    mvdram_gemv(aqb, wq, geom=BANKED)   # warm template/plan caches
    mvdram_gemv(aqs[0], wq, geom=BANKED)
    t_batch, (out_b, rep) = _best_of(
        lambda: mvdram_gemv(aqb, wq, geom=BANKED))
    t_seq, seq = _best_of(
        lambda: [mvdram_gemv(a, wq, geom=BANKED) for a in aqs])

    bit_identical = all(
        np.array_equal(np.asarray(out_1), np.asarray(out_b[b]))
        and [c.asdict() for c in rep_1.tile_runtime]
            == [c.asdict() for c in rep.requests[b].tile_runtime]
        and rep_1.runtime.asdict() == rep.requests[b].runtime.asdict()
        and rep_1.preload.asdict() == rep.requests[b].preload.asdict()
        for b, (out_1, rep_1) in enumerate(seq))

    # analytic shared-wave pricing reconciles with the simulated counts
    cost = mvdram_gemv_cost(M, N, Q, P, geom=BANKED,
                            usable_cols=BANKED.subarray_cols)
    priced = price_gemv_batched(cost, B, geom=BANKED)
    staging_match = (rep.shared_preload.host_bits_written
                     == cost.weight_load_bits == priced.weight_load_bits)
    # non-tautological: the batch ledger must equal the INDEPENDENT
    # sequential-oracle runs' command totals
    runtime_match = rep.runtime.pud_ops == sum(
        r1.runtime.pud_ops for (_o, r1) in seq)

    amortization = t_seq / t_batch
    emit("sim.sequential_b4_banked_512x256_q4p4_ms", t_seq * 1e3)
    emit("sim.batched_b4_banked_512x256_q4p4_ms", t_batch * 1e3)
    emit("sim.batch_amortization_x", amortization,
         f"bit_identical={bit_identical} waves={rep.waves} "
         f"shared_preload_bits={rep.shared_preload.host_bits_written} "
         f"amortized_bits={rep.amortized_preload_bits}")
    emit("sim.batch_price_amortization_x", priced.amortization,
         f"staging_match={staging_match} runtime_match={runtime_match}")
    assert bit_identical, "batched GeMV diverged from the sequential oracle"
    assert staging_match, "analytic weight staging != simulated shared counts"
    assert runtime_match, "batch runtime != sum of per-request runtimes"
    assert rep.waves == 4, f"expected 4 waves, got {rep.waves}"
    assert rep.schedule.reuse_factor == B
    assert amortization >= 2.0, \
        f"amortization {amortization:.2f}x below the 2x floor"


def kernel_dots_issued(emit):
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(N, M)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(4, N)), jnp.float32)
    bw = make_bitplane_weights(w, QuantSpec(bits=Q))
    spec = QuantSpec(bits=P)
    emit("kernel.bitserial_dots_per_tile", dots_per_tile(Q, P, "bitserial"))
    emit("kernel.code_dots_per_tile", dots_per_tile(Q, P, "code"),
         "the §V-D linearity collapse: q instead of q·p")
    outs = {}
    for fid in ("bitserial", "code"):
        def f(x, fid=fid):
            return bp.bitplane_gemv_bitserial(x, bw, spec,
                                              impl="pallas_interpret",
                                              fidelity=fid)
        f(a).block_until_ready()               # compile outside the timer
        t0 = time.perf_counter()
        for _ in range(5):
            out = f(a)
        out.block_until_ready()
        outs[fid] = out
        emit(f"kernel.{fid}_interpret_us", (time.perf_counter() - t0) / 5 * 1e6)
    rel = float(jnp.abs(outs["code"] - outs["bitserial"]).max()
                / (jnp.abs(outs["bitserial"]).max() + 1e-9))
    emit("kernel.code_vs_bitserial_relerr", rel, "must be <= 1e-4")
    assert rel <= 1e-4


ALL = [sim_vectorized_vs_naive, sim_wave_vs_sequential,
       sim_batched_wave_sharing, kernel_dots_issued]

"""Simulator + kernel-schedule benchmarks for the template architecture.

Measures (1) PUD-simulator GeMV wall-clock, naive micro-op oracle vs the
template-selected vectorized executor, on the paper-representative 512×256
q=4/p=4 shape — asserting the ≥20× acceptance floor and bit-identical
outputs/OpCounts; (2) wave-parallel BankArray dispatch vs the sequential
per-tile template path at banked geometry (256 tiles → 4 waves) — asserting
the ≥5× acceptance floor, bit-identical outputs AND per-tile OpCounts;
(3) cross-request wave sharing: one B=4 batched GeMV launch vs 4 sequential
launches at the same banked geometry — asserting the ≥2× amortization
floor, per-request outputs AND per-tile OpCounts bit-identical to the
sequential oracle, and `price_gemv_batched`'s amortized weight staging
reconciling with the simulator's shared-wave counts; (4) multi-layer
RESIDENT decode: a 4-layer block compiled into one `GemvProgram` (weights
staged once by the residency pool, q/k/v waves fused) vs per-layer
sequential staging — asserting the ≥1.5× wall-clock floor, bit-identical
outputs/per-tile runtime OpCounts, ZERO repeated weight staging, and exact
staging reconciliation against the pool placements; (5) FUSED wave-major
program execution (the simulator walks `schedule_program`'s fused slot
order directly, one batched step per global wave) vs the retained
layer-major oracle on the same 4-layer q4/p2 B=2 block — asserting the
≥1.3× floor, bit-identical outputs AND per-tile OpCounts, executed fused
waves == the compiled schedule's, and `price_program(executed=…)`
reconciling against the measured per-wave serialization; (6) per-command
ENERGY of the executed decode step (`EnergyModel`): `ProgramCost.e_total`
reconciled float-exactly against the simulator's per-command `OpCounts`
ledger on clean, faulted (`e_retry`) and CXL-spill (`e_spill`) runs, the
same step at the LPDDR5 (CD-PIM) geometry, the real-column energy ratio
vs the CPU baseline, and the speculative encode/wave overlap ratio
(layer k+1's host encode hidden under layer k's waves); and (7) the MXU
dots issued per tile by the bit-serial Pallas kernel's decomposed schedule
vs the §V-D code-dot fast path (q·p vs q), plus measured interpret-mode
wall-clock for both fidelities.

    PYTHONPATH=src python -m benchmarks.sim_bench --json
        runs everything and writes BENCH_sim.json (per-shape wall-clock +
        speedup ratios) so the perf trajectory is tracked across PRs.
    PYTHONPATH=src python -m benchmarks.sim_bench --json BENCH_new.json --smoke
        the pull-request gate: the (slow) Pallas-interpret kernel section
        is skipped. Benchmark SHAPES and the best-of-5 measurement are
        unchanged so every speedup/amortization row stays directly
        comparable to the committed full-run BENCH_sim.json baseline
        (`benchmarks/check_regression.py --max-drop`).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.bitplane import make_bitplane_weights
from repro.core.engine import MVDRAMEngine
from repro.core.pud.gemv import PudGeometry, mvdram_gemv, mvdram_gemv_cost
from repro.core.pud.timing import (price_gemv, price_gemv_batched,
                                   simulated_wave_time)
from repro.core.quant import (QuantSpec, quantize_activations,
                              quantize_weights)

N, M, Q, P = 512, 256, 4, 4
# Banked geometry for the wave benchmark: 16 reduction chunks × 16 column
# chunks = 256 tiles over 64 concurrent subarrays → 4 waves.
BANKED = PudGeometry(subarray_cols=64, n_sub_max=32)

# measurement repetitions (best-of-N). The fast denominators (wave/fused
# paths, ~5-10 ms) are the noisy side of every ratio; best-of-5 converges
# them to the true min closely enough for the PR gate's 25% drop threshold
# (single-rep and best-of-3 measurements were observed to swing >25% under
# runner load). --smoke keeps N=5 so smoke rows compare like-for-like
# against the committed full-run baseline.
_REPS = 5


# Measured-timing floors are hard asserts on full runs. Under --smoke they
# are tolerated (printed, not fatal): the PR gate takes the per-row BEST
# of two independent smoke runs precisely because one run can hit a
# transient contention window — an in-run fatal assert would abort before
# the second run could absorb it. Correctness asserts (bit-identity,
# reconciliation) are ALWAYS fatal; only wall-clock floors soften.
_FLOORS_FATAL = True


def _assert_floor(value: float, floor: float, msg: str) -> None:
    if value >= floor:
        return
    if _FLOORS_FATAL:
        raise AssertionError(msg)
    print(f"# smoke: tolerated measured-floor miss ({msg}); "
          f"the cross-run regression gate decides")


def _best_of(fn, reps: int | None = None):
    best, ret = float("inf"), None
    for _ in range(reps if reps is not None else _REPS):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        if dt < best:
            best, ret = dt, out
    return best, ret


def sim_vectorized_vs_naive(emit):
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(N, M)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    wq = quantize_weights(w, QuantSpec(bits=Q))
    aq = quantize_activations(a, QuantSpec(bits=P))

    t0 = time.perf_counter()
    out_v, rep_v = mvdram_gemv(aq, wq)
    t_vec = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_n, rep_n = mvdram_gemv(aq, wq, naive=True)
    t_naive = time.perf_counter() - t0

    bit_identical = (np.array_equal(np.asarray(out_v), np.asarray(out_n))
                     and rep_v.runtime.asdict() == rep_n.runtime.asdict())
    speedup = t_naive / t_vec
    emit("sim.naive_512x256_q4p4_ms", t_naive * 1e3)
    emit("sim.vectorized_512x256_q4p4_ms", t_vec * 1e3)
    emit("sim.vectorized_speedup_x", speedup,
         f"bit_identical={bit_identical} pud_ops={rep_v.runtime.pud_ops}")
    assert bit_identical, "vectorized sim diverged from the naive oracle"
    _assert_floor(speedup, 20.0,
                  f"speedup {speedup:.1f}x below the 20x floor")


def sim_wave_vs_sequential(emit):
    """Wave-parallel BankArray dispatch vs the sequential template path at
    banked geometry — the §VII channel/bank concurrency win on top of PR 1's
    template vectorization."""
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(size=(N, M)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    wq = quantize_weights(w, QuantSpec(bits=Q))
    aq = quantize_activations(a, QuantSpec(bits=P))

    mvdram_gemv(aq, wq, geom=BANKED)  # warm template/plan caches
    t_wave, (out_w, rep_w) = _best_of(lambda: mvdram_gemv(aq, wq, geom=BANKED))
    t_seq, (out_s, rep_s) = _best_of(
        lambda: mvdram_gemv(aq, wq, geom=BANKED, wave=False))

    bit_identical = (
        np.array_equal(np.asarray(out_w), np.asarray(out_s))
        and [c.asdict() for c in rep_w.tile_runtime]
            == [c.asdict() for c in rep_s.tile_runtime]
        and rep_w.runtime.asdict() == rep_s.runtime.asdict())
    speedup = t_seq / t_wave
    emit("sim.sequential_banked_512x256_q4p4_ms", t_seq * 1e3)
    emit("sim.wave_banked_512x256_q4p4_ms", t_wave * 1e3)
    emit("sim.wave_speedup_x", speedup,
         f"bit_identical={bit_identical} tiles={rep_w.tiles} "
         f"waves={rep_w.waves}")
    assert bit_identical, "wave sim diverged from the sequential oracle"
    assert rep_w.waves == 4, f"expected 4 waves, got {rep_w.waves}"
    _assert_floor(speedup, 5.0,
                  f"speedup {speedup:.1f}x below the 5x floor")


def sim_batched_wave_sharing(emit):
    """Cross-request wave sharing: B=4 activation vectors against one
    resident matrix in shared waves vs 4 independent sequential launches.
    The per-wave weight staging happens once for the batch; outputs and
    per-tile OpCounts of every request must be bit-identical to its
    sequential-oracle run, and the analytic `price_gemv_batched` must
    reconcile with the simulator's shared-wave staging counts."""
    B = 4
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(N, M)), jnp.float32)
    A = jnp.asarray(rng.normal(size=(B, N)), jnp.float32)
    wq = quantize_weights(w, QuantSpec(bits=Q))
    aqb = quantize_activations(A, QuantSpec(bits=P))
    aqs = [quantize_activations(A[b], QuantSpec(bits=P)) for b in range(B)]

    mvdram_gemv(aqb, wq, geom=BANKED)   # warm template/plan caches
    mvdram_gemv(aqs[0], wq, geom=BANKED)
    t_batch, (out_b, rep) = _best_of(
        lambda: mvdram_gemv(aqb, wq, geom=BANKED))
    t_seq, seq = _best_of(
        lambda: [mvdram_gemv(a, wq, geom=BANKED) for a in aqs])

    bit_identical = all(
        np.array_equal(np.asarray(out_1), np.asarray(out_b[b]))
        and [c.asdict() for c in rep_1.tile_runtime]
            == [c.asdict() for c in rep.requests[b].tile_runtime]
        and rep_1.runtime.asdict() == rep.requests[b].runtime.asdict()
        and rep_1.preload.asdict() == rep.requests[b].preload.asdict()
        for b, (out_1, rep_1) in enumerate(seq))

    # analytic shared-wave pricing reconciles with the simulated counts
    cost = mvdram_gemv_cost(M, N, Q, P, geom=BANKED,
                            usable_cols=BANKED.subarray_cols)
    priced = price_gemv_batched(cost, B, geom=BANKED)
    staging_match = (rep.shared_preload.host_bits_written
                     == cost.weight_load_bits == priced.weight_load_bits)
    # non-tautological: the batch ledger must equal the INDEPENDENT
    # sequential-oracle runs' command totals
    runtime_match = rep.runtime.pud_ops == sum(
        r1.runtime.pud_ops for (_o, r1) in seq)

    amortization = t_seq / t_batch
    emit("sim.sequential_b4_banked_512x256_q4p4_ms", t_seq * 1e3)
    emit("sim.batched_b4_banked_512x256_q4p4_ms", t_batch * 1e3)
    emit("sim.batch_amortization_x", amortization,
         f"bit_identical={bit_identical} waves={rep.waves} "
         f"shared_preload_bits={rep.shared_preload.host_bits_written} "
         f"amortized_bits={rep.amortized_preload_bits}")
    emit("sim.batch_price_amortization_x", priced.amortization,
         f"staging_match={staging_match} runtime_match={runtime_match}")
    assert bit_identical, "batched GeMV diverged from the sequential oracle"
    assert staging_match, "analytic weight staging != simulated shared counts"
    assert runtime_match, "batch runtime != sum of per-request runtimes"
    assert rep.waves == 4, f"expected 4 waves, got {rep.waves}"
    assert rep.schedule.reuse_factor == B
    _assert_floor(amortization, 2.0,
                  f"amortization {amortization:.2f}x below the 2x floor")


def _resident_block(seed: int = 5, B: int = 2, q_b: int = 4, p_b: int = 2,
                    fault_model=None, fault_policy=None):
    """The 4-layer q4/p2 B=2 resident block (q/k/v-style group of three
    512→256 linears + a 256→512 down projection) shared by the resident,
    fused-execution and fault-injection benchmarks."""
    rng = np.random.default_rng(seed)
    eng = MVDRAMEngine(geom=BANKED, fault_model=fault_model,
                       fault_policy=fault_policy)
    shapes = [(N, M), (N, M), (N, M), (M, N)]
    hs = []
    for i, (n, m) in enumerate(shapes):
        w = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
        hs.append(eng.register(f"layer{i}", w, QuantSpec(bits=q_b),
                               a_spec=QuantSpec(bits=p_b)))
    prog = eng.compile(hs, groups=[[0, 1, 2], [3]])
    X = [jnp.asarray(rng.normal(size=(B, n)), jnp.float32)
         for (n, _m) in shapes]
    return eng, hs, prog, X


def sim_resident_decode(emit):
    """Multi-layer resident decode (residency sessions, ISSUE 4): a 4-layer
    block — a q/k/v-style concurrency group of three 512→256 linears plus a
    256→512 down projection, q=4/p=2, B=2 lanes — compiled into one
    `GemvProgram` whose weights were staged ONCE at placement, vs the same
    four GeMVs launched sequentially with per-call staging. Outputs and
    per-tile runtime OpCounts must be bit-identical; the resident step must
    re-stage NOTHING (reconciled exactly against the pool placements and
    the per-call oracle's preload); measured wall-clock amortization and
    the priced residency speedup (real-DRAM columns, fused q/k/v waves)
    must clear the ≥1.5× floor."""
    B, p_b = 2, 2
    eng, hs, prog, X = _resident_block(B=B, p_b=p_b)
    aqs = [quantize_activations(x, QuantSpec(bits=p_b)) for x in X]

    def run_seq():
        return [mvdram_gemv(aq, h.wq, geom=BANKED, templates=h.templates)
                for aq, h in zip(aqs, hs)]

    prog.run(X)     # warm: staging done, caches hot
    run_seq()
    t_prog, (outs, prep) = _best_of(lambda: prog.run(X))
    t_seq, refs = _best_of(run_seq)

    bit_identical = all(
        np.array_equal(np.asarray(out), np.asarray(o_ref))
        and [c.asdict() for c in rep.requests[b].tile_runtime]
            == [c.asdict() for c in r_ref.requests[b].tile_runtime]
        for out, rep, (o_ref, r_ref) in zip(outs, prep.reports, refs)
        for b in range(B))
    zero_restaging = (prep.repeated_staging.host_bits_written == 0
                      and all(r.shared_preload.host_bits_written == 0
                              for r in prep.reports))
    # exact three-way staging reconciliation: program == pool placements ==
    # what the per-call oracle re-pays every launch
    staged = prep.staged.host_bits_written
    staging_match = (
        staged == sum(h.placement.staged.host_bits_written for h in hs)
        == sum(r_ref.shared_preload.host_bits_written for _o, r_ref in refs))
    priced = eng.price_program(prog, batch=B, usable_cols=BANKED.real_cols)

    amortization = t_seq / t_prog
    emit("sim.resident_seq_4layer_q4p2_b2_ms", t_seq * 1e3)
    emit("sim.resident_program_4layer_q4p2_b2_ms", t_prog * 1e3)
    emit("sim.resident_amortization_x", amortization,
         f"bit_identical={bit_identical} zero_restaging={zero_restaging} "
         f"staged_bits={staged} staging_match={staging_match}")
    emit("sim.resident_price_speedup_x", priced.residency_speedup,
         f"waves={priced.waves} waves_shared={priced.waves_shared} "
         f"weight_load_bits={priced.weight_load_bits}")
    assert bit_identical, "resident program diverged from per-layer oracle"
    assert zero_restaging, "resident decode step re-staged weight rows"
    assert staging_match, "placement staging != oracle preload accounting"
    assert priced.weight_load_bits == 0
    _assert_floor(amortization, 1.5,
                  f"amortization {amortization:.2f}x below the 1.5x floor")
    assert priced.residency_speedup >= 1.5, \
        f"priced speedup {priced.residency_speedup:.2f}x below the 1.5x floor"


def sim_fused_program(emit):
    """Fused cross-layer wave execution (ISSUE 5): the same 4-layer q4/p2
    B=2 resident block, decoded by walking the compiled `ProgramSchedule`'s
    fused slot order directly — one batched simulator step per global wave,
    heterogeneous layouts sharing boundary waves — vs the retained
    layer-major oracle. Outputs and per-tile OpCounts must be bit-identical,
    execution must run exactly the waves the schedule fused (reconciled into
    `price_program(executed=…)`), and the measured wall-clock speedup must
    clear the ≥1.3× floor."""
    B = 2
    eng, hs, prog, X = _resident_block(B=B)

    prog.run(X)                      # warm: staging + fused plan built
    prog.run(X, layer_major=True)
    t_fused, (outs_f, rep_f) = _best_of(lambda: prog.run(X))
    t_layer, (outs_l, rep_l) = _best_of(
        lambda: prog.run(X, layer_major=True))

    # bit-exactness vs the layer-major oracle: outputs AND per-(request,
    # tile) runtime OpCounts (report materialization is lazy — outside the
    # timed region for the fused path, as in a real decode loop)
    bit_identical = all(
        np.array_equal(np.asarray(of), np.asarray(ol))
        and [c.asdict() for c in rf.requests[b].tile_runtime]
            == [c.asdict() for c in rl.requests[b].tile_runtime]
        and rf.runtime.asdict() == rl.runtime.asdict()
        for of, rf, ol, rl in zip(outs_f, rep_f.reports, outs_l,
                                  rep_l.reports)
        for b in range(B))
    executed_match = rep_f.fused and rep_f.waves == prog.sched.waves
    # the program price's bank term now reconciles against the EXECUTED
    # fused-wave serialization, not the scheduled estimate
    priced = eng.price_program(prog, batch=B, executed=rep_f)
    t_sim = simulated_wave_time(rep_f)
    price_reconciles = priced.t_compute >= t_sim > 0.0

    speedup = t_layer / t_fused
    emit("sim.layer_major_4layer_q4p2_b2_ms", t_layer * 1e3)
    emit("sim.fused_wave_4layer_q4p2_b2_ms", t_fused * 1e3)
    emit("sim.fused_wave_speedup_x", speedup,
         f"bit_identical={bit_identical} waves={rep_f.waves} "
         f"scheduled={prog.sched.waves} shared={prog.sched.waves_shared} "
         f"t_sim_us={t_sim * 1e6:.1f}")
    assert bit_identical, "fused execution diverged from layer-major oracle"
    assert executed_match, (
        f"executed {rep_f.waves} fused waves, schedule has "
        f"{prog.sched.waves}")
    assert price_reconciles, "executed-wave pricing failed to reconcile"
    _assert_floor(speedup, 1.3,
                  f"fused speedup {speedup:.2f}x below the 1.3x floor")


def sim_fault_injection(emit):
    """Fault-injected PUD (ISSUE 6): seeded MAJX fault injection under the
    ABFT checksum verifier. Three rows: (1) detection coverage at a fixed
    transient BER over resident decode steps of the 4-layer block — every
    injection is a single-bit column flip, so the GeMV-linearity checksum
    must catch 100% (the ≥99% acceptance floor is a hard assert); (2) the
    priced retry overhead — faulty-step `t_total` (executed reconciliation
    including the `t_retry` term) over the clean step's; (3) degraded-mode
    throughput — a persistent fault storm degrades a linear to the host
    `jnp` backend through quarantine + fallback budgets, and the degraded
    step (still serving, correct results) is timed against the healthy
    simulated step."""
    from repro.core import backends
    from repro.core.pud.faults import FaultModel, FaultPolicy

    B, p_b = 2, 2
    # ① + ② transient BER on the resident block (~2048 (request, tile)
    # cells per decode step)
    fm = FaultModel(transient_ber=2e-3, seed=17)
    eng_f, _hs_f, prog_f, X = _resident_block(
        B=B, p_b=p_b, fault_model=fm,
        fault_policy=FaultPolicy(max_wave_retries=4, degrade_after=10**6))
    eng_c, _hs_c, prog_c, _ = _resident_block(B=B, p_b=p_b)
    outs_c, rep_c = prog_c.run(X)
    corrupted = detected = 0
    rep_retry = None
    for _ in range(12):
        outs, rep = prog_f.run(X)
        tr = rep.fault
        corrupted += tr.corrupted
        detected += tr.detected
        if tr.retries and not tr.unresolved:
            rep_retry = rep
            for o, oc in zip(outs, outs_c):
                assert np.array_equal(np.asarray(o), np.asarray(oc)), \
                    "retried decode step diverged from the clean block"
    assert corrupted > 0, "transient BER never fired — raise the cell count"
    coverage = detected / corrupted
    emit("sim.fault_detection_coverage", coverage,
         f"corrupted={corrupted} detected={detected} ber=2e-3 "
         f"(single-bit flips: coverage is exact)")
    assert coverage >= 0.99, \
        f"ABFT coverage {coverage:.4f} below the 0.99 acceptance floor"
    assert rep_retry is not None, "no fully-retried step to price"
    cost_c = eng_c.price_program(prog_c, batch=B, executed=rep_c)
    cost_f = eng_f.price_program(prog_f, batch=B, executed=rep_retry)
    assert cost_f.t_retry > 0.0
    assert abs((cost_f.t_total - cost_f.t_retry) - cost_c.t_total) \
        <= 1e-9 * cost_c.t_total, "retry term failed to reconcile"
    overhead = cost_f.t_total / cost_c.t_total
    emit("sim.fault_retry_overhead_x", overhead,
         f"retry_waves={cost_f.retry_waves} t_retry_us="
         f"{cost_f.t_retry * 1e6:.1f}")

    # ③ persistent fault storm → quarantine → host degradation, still serving
    storm = FaultModel(weak_cell_rate=0.05, weak_flip_prob=1.0, seed=23)
    pol = FaultPolicy(max_wave_retries=1, quarantine_after=1, degrade_after=1)
    eng_s = MVDRAMEngine(geom=BANKED, fault_model=storm, fault_policy=pol)
    rng = np.random.default_rng(29)
    w = jnp.asarray(rng.normal(size=(N, M)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, N)), jnp.float32)
    h_s = eng_s.register("w", w, QuantSpec(bits=Q), a_spec=QuantSpec(bits=p_b))
    eng_s.gemv(h_s, x, backend=backends.SIM)        # trips the full ladder
    assert eng_s.is_degraded(h_s), "fault storm failed to degrade the linear"
    st = eng_s.residency_stats()
    eng_h = MVDRAMEngine(geom=BANKED)
    h_h = eng_h.register("w", w, QuantSpec(bits=Q), a_spec=QuantSpec(bits=p_b))
    eng_h.gemv(h_h, x, backend=backends.SIM)        # warm caches
    t_sim, (out_sim, _r) = _best_of(
        lambda: eng_h.gemv(h_h, x, backend=backends.SIM))
    eng_s.gemv(h_s, x, backend=backends.SIM)        # warm the jnp route
    t_deg, (out_deg, rep_deg) = _best_of(
        lambda: eng_s.gemv(h_s, x, backend=backends.SIM))
    assert rep_deg is None                          # host route, no sim stream
    np.testing.assert_allclose(np.asarray(out_sim), np.asarray(out_deg),
                               rtol=2e-5, atol=1e-5)
    ratio = t_sim / t_deg
    emit("sim.fault_degraded_throughput_x", ratio,
         f"degraded (host jnp) step vs healthy simulated step; "
         f"quarantined_banks={st['quarantined_banks']} "
         f"fallbacks={st['fault_host_fallbacks']} still_correct=True")
    assert ratio > 0.0


def sim_energy_overlap(emit):
    """Per-command energy accounting + speculative encode overlap (ISSUE
    10), four rows on the 4-layer q4/p2 B=2 resident block: (1) the
    DDR4-priced energy of one EXECUTED decode step (`ProgramCost.e_total`),
    reconciled EXACTLY — float-equal, not approximate — against the
    per-command `OpCounts` ledger the simulator billed (activate/precharge
    per MAJX/RowCopy, readout + staging bus bits, host encode ops, idle
    draw over the step); (2) the same executed ledger re-priced at the
    LPDDR5 (CD-PIM) energy geometry; (3) the paper-scale energy ratio —
    CPU-baseline step energy over the MVDRAM step priced at real DRAM
    columns (the tiny 64-col bench geometry would overstate the DRAM
    side); (4) the speculative encode/wave overlap — layer k+1's host
    activation encode runs under layer k's waves, so the measured pipeline
    exposes only `t_encode_extra` of the full `t_encode`, and
    `encode_overlap_speedup` is what a host that serialized every encode
    in front of compute would pay instead. Exact reconciliation is
    additionally asserted on a FAULTED run (the retry ledger re-bills
    per-command as `e_retry`) and a CXL SPILL run (page-in bits as
    `e_spill`)."""
    from benchmarks.fabric_bench import (SPILL_GEOM, SPILL_LAYERS,
                                         SPILL_RESERVE)
    from repro.core.pud.device import _COUNT_FIELDS, OpCounts
    from repro.core.pud.fabric import FabricPool
    from repro.core.pud.faults import FaultModel, FaultPolicy
    from repro.core.pud.timing import (DDR4_ENERGY, LPDDR5_CDPIM,
                                       CpuBaseline)

    def expected_energy(cost, rep, energy):
        # mirrors price_program's executed branch COMPONENT ORDER exactly,
        # so the equalities below are float-bit equality, not tolerance
        retry_c = rep.retry_counts
        base_c = OpCounts(*(getattr(rep.executed_counts, f)
                            - getattr(retry_c, f) for f in _COUNT_FIELDS))
        e_pud = energy.pud_energy(base_c)
        e_io = energy.io_energy(base_c.host_bits_read
                                + base_c.host_bits_written)
        e_host = (energy.host_energy(base_c.host_int_ops)
                  + energy.idle_power * cost.t_compute)
        e_retry = energy.ledger_energy(retry_c)
        e_spill = energy.io_energy(cost.spill_restage_bits)
        return e_pud + e_io + e_host + e_retry + e_spill

    B, q_b, p_b = 2, 4, 2
    eng, hs, prog, X = _resident_block(B=B, q_b=q_b, p_b=p_b)
    outs, rep = prog.run(X)
    assert rep.executed_counts is not None, "fused run must carry a ledger"
    cost = eng.price_program(prog, batch=B, executed=rep)
    assert cost.e_retry == 0.0 and cost.e_spill == 0.0
    assert cost.e_total == expected_energy(cost, rep, DDR4_ENERGY), \
        "priced e_total diverged from the executed per-command ledger"
    emit("sim.energy_step_ddr4_j", cost.e_total,
         f"per-command DDR4 ledger: e_pud={cost.e_pud:.3g} "
         f"e_io={cost.e_io:.3g} e_host={cost.e_host:.3g} (exact)")

    # ② the same executed ledger at the LPDDR5 (CD-PIM) energy geometry
    eng.energy = LPDDR5_CDPIM
    try:
        cost_lp = eng.price_program(prog, batch=B, executed=rep)
    finally:
        eng.energy = DDR4_ENERGY
    assert cost_lp.e_total == expected_energy(cost_lp, rep, LPDDR5_CDPIM)
    assert 0.0 < cost_lp.e_total < cost.e_total, \
        "LPDDR5 (CD-PIM) step energy should undercut DDR4"
    emit("sim.energy_step_lpddr5_j", cost_lp.e_total,
         "same executed ledger at the LPDDR5 (CD-PIM) energy geometry")

    # ③ paper-scale ratio vs the CPU baseline. The bench block's 512→256
    # layers fill 3% of a real 8192-column DRAM row, so at real geometry
    # their per-command energy honestly LOSES to the CPU — MVDRAM's win is
    # an LLM-scale effect. Price the paper's anchor GeMV shape (32000×4096,
    # the A2/A3 matrix) per-command at real columns instead: analytic and
    # registration-free, so paper scale costs nothing to evaluate.
    m_a, n_a = 32000, 4096
    mv = mvdram_gemv_cost(m_a, n_a, q_b, p_b, geom=BANKED)
    pc = price_gemv(mv, BANKED)
    e_mv = (DDR4_ENERGY.pud_energy(mv.runtime)
            + DDR4_ENERGY.io_energy(mv.runtime.host_bits_read
                                    + mv.runtime.host_bits_written)
            + DDR4_ENERGY.host_energy(mv.runtime.host_int_ops
                                      + mv.encode_host_ops)
            + DDR4_ENERGY.idle_power * pc.t_compute)
    e_cpu = CpuBaseline().gemv_energy(m_a, n_a, q_b, p_b)
    ratio = e_cpu / e_mv
    emit("sim.energy_ratio_vs_cpu", ratio,
         f"CPU {e_cpu:.3g} J / MVDRAM {e_mv:.3g} J on the paper-scale "
         f"{m_a}x{n_a} q{q_b}/p{p_b} anchor GeMV (per-command, real cols)")
    assert ratio > 1.0, \
        f"MVDRAM anchor-GeMV energy should beat the CPU, got {ratio:.3f}x"

    # ④ speculative encode overlap: deterministic priced pipeline ratio
    assert cost.t_encode > 0.0
    speedup = cost.encode_overlap_speedup
    emit("sim.overlap_speedup_x", speedup,
         f"t_encode={cost.t_encode * 1e6:.1f}us exposed="
         f"{cost.t_encode_extra * 1e6:.1f}us (layer k+1 encodes under "
         f"layer k's waves)")
    assert speedup > 1.0, \
        f"speculative encode overlap bought nothing: {speedup:.5f}x"

    # faulted run: the retry ledger re-bills per-command as e_retry
    fm = FaultModel(transient_ber=2e-3, seed=17)
    eng_f, _hs_f, prog_f, _ = _resident_block(
        B=B, q_b=q_b, p_b=p_b, fault_model=fm,
        fault_policy=FaultPolicy(max_wave_retries=4, degrade_after=10**6))
    rep_retry = None
    for _ in range(12):
        _outs_f, rep_f = prog_f.run(X)
        if rep_f.fault.retries and not rep_f.fault.unresolved:
            rep_retry = rep_f
            break
    assert rep_retry is not None, "transient BER never forced a retry"
    cost_f = eng_f.price_program(prog_f, batch=B, executed=rep_retry)
    assert cost_f.e_retry > 0.0
    assert cost_f.e_total == expected_energy(cost_f, rep_retry,
                                             DDR4_ENERGY), \
        "faulted-run e_total failed exact reconciliation (e_retry term)"

    # spill run: CXL page-in bits land as e_spill, still exact
    rng = np.random.default_rng(7)
    ws = [jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
          for _ in range(SPILL_LAYERS)]
    pool = FabricPool(geom=SPILL_GEOM, dimms=1,
                      compute_reserve=SPILL_RESERVE)
    eng_s = MVDRAMEngine(geom=SPILL_GEOM, pool=pool, on_full="spill")
    hs_s = [eng_s.register(f"l{i}", w, QuantSpec(bits=4),
                           a_spec=QuantSpec(bits=4))
            for i, w in enumerate(ws)]
    prog_s = eng_s.compile([h.name for h in hs_s])
    Xs = [jnp.asarray(rng.normal(size=(16,)), jnp.float32) for _ in ws]
    _outs_s, rep_s = prog_s.run(Xs)
    assert rep_s.spill_restage_bits > 0
    cost_s = prog_s.price(batch=1, executed=rep_s)
    assert cost_s.spill_restage_bits == rep_s.spill_restage_bits
    assert cost_s.e_spill == DDR4_ENERGY.io_energy(rep_s.spill_restage_bits)
    assert cost_s.e_spill > 0.0
    # per-PART exactness (the fabric total re-sums the parts in a
    # different float order, so the part is the bit-exact unit)
    for pc_k, rep_k in zip(cost_s.parts, rep_s.parts):
        assert rep_k.executed_counts is not None
        assert pc_k.e_total == expected_energy(pc_k, rep_k, DDR4_ENERGY), \
            "spill-part e_total failed exact reconciliation (e_spill term)"


def kernel_dots_issued(emit):
    from repro.kernels.bitplane_gemv import ops as bp
    from repro.kernels.bitplane_gemv.kernel import dots_per_tile

    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(N, M)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(4, N)), jnp.float32)
    bw = make_bitplane_weights(w, QuantSpec(bits=Q))
    spec = QuantSpec(bits=P)
    emit("kernel.bitserial_dots_per_tile", dots_per_tile(Q, P, "bitserial"))
    emit("kernel.code_dots_per_tile", dots_per_tile(Q, P, "code"),
         "the §V-D linearity collapse: q instead of q·p")
    outs = {}
    for fid in ("bitserial", "code"):
        def f(x, fid=fid):
            return bp.bitplane_gemv_bitserial(x, bw, spec,
                                              impl="pallas_interpret",
                                              fidelity=fid)
        f(a).block_until_ready()               # compile outside the timer
        t0 = time.perf_counter()
        for _ in range(5):
            out = f(a)
        out.block_until_ready()
        outs[fid] = out
        emit(f"kernel.{fid}_interpret_us", (time.perf_counter() - t0) / 5 * 1e6)
    rel = float(jnp.abs(outs["code"] - outs["bitserial"]).max()
                / (jnp.abs(outs["bitserial"]).max() + 1e-9))
    emit("kernel.code_vs_bitserial_relerr", rel, "must be <= 1e-4")
    assert rel <= 1e-4


def kernel_program(emit):
    """Fused whole-block Pallas decode kernel (ISSUE 8): a compiled
    program executed as ONE Pallas launch walking its schedule
    (`kernels/bitplane_gemv/program.py`, `GemvProgram.run_kernel`) vs the
    per-leaf path — one jitted `bitplane_gemv_bitserial` dispatch per
    weight, the ~L launches a decode block cost before.

    Correctness is asserted on the HETEROGENEOUS 4-layer resident block
    (ragged bn, grouped q/k/v, the hard case for the one-launch padding
    algebra): bit-identical outputs and exactly ONE trace-time launch.
    The speedup row is timed on a uniform 8-layer thin block (256->128,
    q2/p2, B=2) where the fused envelope pads nothing, so fused and
    per-leaf execute IDENTICAL integer work and the row isolates what
    fusion actually buys: L-1 avoided host dispatches per decode step
    plus one batched activation quantization — the B<=8 dispatch-bound
    decode regime the program path exists for. (The resident block's
    mixed bn would hide that behind envelope-padding MACs: its layer-3
    tiles pad 256->512 and interpret-mode compute swamps dispatch.)"""
    from repro.kernels.bitplane_gemv import ops as bp
    from repro.kernels.bitplane_gemv import program as bp_prog

    B, p_b = 2, 2
    eng, hs, prog, X = _resident_block(B=B, p_b=p_b)
    spec = QuantSpec(bits=p_b)

    def per_leaf():
        outs = [bp.bitplane_gemv_bitserial(x, h.weights, spec,
                                           impl="pallas_interpret")
                for x, h in zip(X, hs)]
        outs[-1].block_until_ready()
        return outs

    def fused():
        outs = prog.run_kernel(X, interpret=True)
        outs[-1].block_until_ready()
        return outs

    l0 = bp_prog.LAUNCHES
    outs_f = fused()                  # first call traces the ONE launch
    launches = bp_prog.LAUNCHES - l0
    outs_l = per_leaf()
    bit_identical = all(np.array_equal(np.asarray(a), np.asarray(b))
                        for a, b in zip(outs_f, outs_l))
    assert bit_identical, "fused program kernel != per-leaf outputs"
    assert launches == 1, f"{launches} launches for one decode block"

    # dispatch-bound timing block: uniform layers, zero envelope padding
    L_u, n_u, m_u = 8, 256, 128
    rng = np.random.default_rng(11)
    eng_u = MVDRAMEngine(geom=BANKED)
    hs_u, X_u = [], []
    for i in range(L_u):
        w = jnp.asarray(rng.normal(size=(n_u, m_u)), jnp.float32)
        hs_u.append(eng_u.register(f"uni{i}", w, QuantSpec(bits=2),
                                   a_spec=QuantSpec(bits=2)))
        X_u.append(jnp.asarray(rng.normal(size=(B, n_u)), jnp.float32))
    prog_u = eng_u.compile(hs_u, groups=[list(range(L_u))])
    spec_u = QuantSpec(bits=2)

    STEPS = 10                        # steady-state decode loop per rep:
                                      # single-step timings swing 2-3x with
                                      # host dispatch jitter; amortizing 10
                                      # steps per measurement stabilizes the
                                      # ratio the gate tracks

    def per_leaf_u():
        for _ in range(STEPS):
            outs = [bp.bitplane_gemv_bitserial(x, h.weights, spec_u,
                                               impl="pallas_interpret")
                    for x, h in zip(X_u, hs_u)]
        outs[-1].block_until_ready()
        return outs

    def fused_u():
        for _ in range(STEPS):
            outs = prog_u.run_kernel(X_u, interpret=True)
        outs[-1].block_until_ready()
        return outs

    outs_fu = fused_u()               # warm (pack weights + trace)
    outs_lu = per_leaf_u()
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(outs_fu, outs_lu)), \
        "uniform-block fused kernel != per-leaf outputs"

    t_fused, _ = _best_of(fused_u)
    t_leaf, _ = _best_of(per_leaf_u)
    t_fused, t_leaf = t_fused / STEPS, t_leaf / STEPS
    speedup = t_leaf / t_fused
    emit("kernel.program_launches_per_block", launches,
         "trace-time pallas_call count on the fused 4-layer resident block")
    emit("kernel.program_decode_ms", t_fused * 1e3,
         "one fused launch for the whole 8-layer uniform decode block")
    emit("kernel.program_perleaf_ms", t_leaf * 1e3,
         "the per-leaf path: one jitted dispatch per weight leaf")
    emit("kernel.program_fusion_speedup_x", speedup,
         "per-leaf dispatch / fused whole-block launch wall-clock")
    _assert_floor(speedup, 1.3,
                  f"program fusion speedup {speedup:.2f}x below 1.3x floor")


from benchmarks.fabric_bench import sim_fabric  # noqa: E402
from benchmarks.serve_traffic import sim_serve_traffic  # noqa: E402

ALL = [sim_vectorized_vs_naive, sim_wave_vs_sequential,
       sim_batched_wave_sharing, sim_resident_decode, sim_fused_program,
       sim_fault_injection, sim_energy_overlap, sim_serve_traffic,
       sim_fabric, kernel_dots_issued, kernel_program]

# skipped under --smoke: Pallas interpret-mode timing is the long pole and
# emits no gated ratio rows. The serve-traffic horizon stays in smoke:
# its rows are require-rows-guarded (not drop-gated), but its internal
# bit-exactness/price-reconciliation asserts surface as recorded errors
# the PR gate fails on. `kernel_program` also stays in smoke: its
# `kernel.program_fusion_speedup_x` row IS drop-gated, and the PR gate
# fails on a gated baseline row missing from the new runs.
_SLOW = {kernel_dots_issued}


# ---------------------------------------------------------------------------
# Machine-readable output: BENCH_sim.json tracks the perf trajectory
# ---------------------------------------------------------------------------

def main() -> None:
    import argparse
    import json
    import platform

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_sim.json",
                    default=None, metavar="PATH",
                    help="write per-shape wall-clock + speedup rows as JSON "
                         "(default path: BENCH_sim.json)")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark function names")
    ap.add_argument("--smoke", action="store_true",
                    help="pull-request gate config: the slow Pallas-"
                         "interpret kernel section is skipped; simulator "
                         "shapes and best-of-5 measurement are unchanged "
                         "so every speedup row stays directly comparable "
                         "to the committed full-run baseline")
    args = ap.parse_args()

    if args.smoke:
        global _FLOORS_FATAL
        _FLOORS_FATAL = False

    rows: list = []

    def emit(name, value, derived=""):
        rows.append({"name": name, "value": value, "derived": derived})
        v = f"{value:.6g}" if isinstance(value, float) else value
        print(f"{name},{v},{derived}")

    errors = []
    for fn in ALL:
        if args.only and args.only not in fn.__name__:
            continue
        if args.smoke and fn in _SLOW:
            continue
        try:
            fn(emit)
        except Exception as e:  # noqa: BLE001 — record and continue
            errors.append({"bench": fn.__name__, "error": repr(e)[:200]})
            print(f"{fn.__name__}.ERROR,0,{repr(e)[:200]}")
    if args.json:
        doc = {
            "schema": 1,
            "suite": "sim_bench",
            "platform": platform.platform(),
            "python": platform.python_version(),
            "rows": rows,
            "errors": errors,
            "speedups": {r["name"]: r["value"] for r in rows
                         if r["name"].endswith(("_x", "_speedup"))},
            "wall_clock_ms": {r["name"]: r["value"] for r in rows
                              if r["name"].endswith("_ms")},
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}: {len(rows)} rows, "
              f"{len(errors)} errors")
    if errors:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
